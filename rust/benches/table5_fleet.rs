//! Fleet serving throughput: one `ConvService` worker vs an N-shard
//! `FleetDispatcher` on the same concurrent-client soak workload.
//!
//! The paper's end-to-end speedups (Table 5) only reach production if the
//! serving layer keeps many workers saturated; this bench records the
//! aggregate rows/sec of the single-worker service (stock native backend,
//! engine-internal row fan-out) against a sharded fleet whose workers are
//! each single-threaded (`NativeRowThreads(1)`) — shard-level parallelism
//! instead of per-engine thread pools. Emits `BENCH_fleet.json` so the
//! fleet-vs-single trajectory accumulates across PRs.
//!
//! Env knobs: `FFC_FLEET_SHARDS` (default 4), `FFC_FLEET_REQUESTS` (total,
//! default 384), `FFC_FLEET_CLIENTS` (default 8).

use std::time::{Duration, Instant};

use flashfftconv::bench::{fmt_x, BenchRecord, Table};
use flashfftconv::coordinator::fleet::{FleetConfig, FleetDispatcher, LatencyHistogram};
use flashfftconv::coordinator::router::ConvKind;
use flashfftconv::coordinator::service::{ConvProfile, ConvRequest};
use flashfftconv::coordinator::BatchPolicy;
use flashfftconv::runtime::BackendConfig;
use flashfftconv::util::Rng;

const HEADS: usize = 16;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn request(rng: &mut Rng, slot: usize) -> ConvRequest {
    // Mixed lengths: mostly the 256 bucket (some padded), every 4th
    // request the 1024 bucket — same mix as the fleet soak test.
    let len = match slot % 4 {
        0 => 1024,
        1 => 200, // pads into 256
        _ => 256,
    };
    ConvRequest { kind: ConvKind::Forward, len, streams: vec![rng.normal_vec(HEADS * len)], chunk_tx: None }
}

/// Drive `total` requests from `clients` closed-loop client threads
/// (window of 8 outstanding each); returns (rows served, wall clock).
fn drive(fleet: &FleetDispatcher<ConvProfile>, clients: usize, total: usize) -> (u64, Duration) {
    let before = fleet.stats().rows_executed;
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for c in 0..clients {
            s.spawn(move || {
                let mut rng = Rng::new(7_000 + c as u64);
                let per_client = total / clients.max(1);
                let mut pending = std::collections::VecDeque::new();
                for i in 0..per_client {
                    let mut req = request(&mut rng, i + c);
                    loop {
                        match fleet.try_submit(req) {
                            Ok(rx) => {
                                pending.push_back(rx);
                                break;
                            }
                            Err((r, e)) if e.retryable() => {
                                req = r;
                                match pending.pop_front() {
                                    // Backpressure: drain one of ours, retry.
                                    Some(rx) => {
                                        rx.recv().expect("fleet alive").expect("conv ok");
                                    }
                                    None => std::thread::sleep(Duration::from_micros(200)),
                                }
                            }
                            Err((_, e)) => panic!("submit failed: {e}"),
                        }
                    }
                    while pending.len() >= 8 {
                        let rx = pending.pop_front().unwrap();
                        rx.recv().expect("fleet alive").expect("conv ok");
                    }
                }
                for rx in pending {
                    rx.recv().expect("fleet alive").expect("conv ok");
                }
            });
        }
    });
    let wall = t0.elapsed();
    (fleet.stats().rows_executed - before, wall)
}

fn warmup(fleet: &FleetDispatcher<ConvProfile>, n_shards: usize) {
    // Touch every bucket on every shard so artifact loads (and plan
    // construction) stay out of the measured window. A *concurrent* burst
    // per bucket is what spreads the work: sequential blocking calls at
    // zero outstanding would always land on the bucket's affinity shard
    // and leave the other shards cold.
    let mut rng = Rng::new(1);
    for len in [256usize, 1024, 200] {
        let pending: Vec<_> = (0..2 * n_shards)
            .map(|_| {
                let u = rng.normal_vec(HEADS * len);
                fleet
                    .submit_blocking(ConvRequest { kind: ConvKind::Forward, len, streams: vec![u], chunk_tx: None })
                    .expect("warmup burst admitted")
            })
            .collect();
        for rx in pending {
            rx.recv().expect("fleet alive").expect("warmup conv ok");
        }
    }
}

fn main() {
    let shards = env_usize("FFC_FLEET_SHARDS", 4).max(1);
    let total = env_usize("FFC_FLEET_REQUESTS", 384).max(16);
    let clients = env_usize("FFC_FLEET_CLIENTS", 8).max(1);
    let policy = BatchPolicy { batch_size: 2, max_wait: Duration::from_millis(2) };

    println!("== Fleet serving throughput: 1 worker vs {shards} shards ==");
    println!("   {total} requests from {clients} clients, mixed 256/1024 buckets\n");

    let mut records: Vec<BenchRecord> = vec![];
    let mut t = Table::new(&["config", "rows", "secs", "rows_per_s", "p50_ms", "p99_ms", "busy"]);
    let mut rates = vec![];

    let cases = [
        ("serve_conv_single", BackendConfig::Native, 1usize, usize::MAX),
        ("serve_conv_fleet", BackendConfig::NativeRowThreads(1), shards, 8 * shards.max(2)),
    ];
    for (name, backend, n_shards, max_inflight) in cases {
        let fleet = FleetDispatcher::conv(
            backend,
            "monarch",
            FleetConfig { shards: n_shards, max_inflight, policy: policy.clone() },
        )
        .expect("fleet starts");
        warmup(&fleet, n_shards);
        // Interval quantiles: diff the histogram around the drive window
        // so warmup compile/load spikes never contaminate the latencies.
        let base = fleet.latency_counts();
        let (rows, wall) = drive(&fleet, clients, total);
        let mut window = fleet.latency_counts();
        for (w, b) in window.iter_mut().zip(base.iter()) {
            *w -= b;
        }
        let p50 = LatencyHistogram::quantile_ms(&window, 0.50);
        let p95 = LatencyHistogram::quantile_ms(&window, 0.95);
        let p99 = LatencyHistogram::quantile_ms(&window, 0.99);
        let stats = fleet.stats();
        assert_eq!(stats.errors, 0, "soak workload must be error-free");
        let rate = rows as f64 / wall.as_secs_f64();
        rates.push(rate);
        t.row(vec![
            format!("{name} (x{n_shards})"),
            rows.to_string(),
            format!("{:.2}", wall.as_secs_f64()),
            format!("{rate:.1}"),
            format!("{p50:.2}"),
            format!("{p99:.2}"),
            stats.busy_rejections.to_string(),
        ]);
        // Encode throughput in the shared record schema: mean_ns = wall,
        // median_ns = per-row wall (rows/sec = 1e9 / median_ns), p95_ns
        // from the drive-window latency histogram.
        records.push(BenchRecord {
            name: name.to_string(),
            n: rows as usize,
            mean_ns: wall.as_nanos() as f64,
            median_ns: wall.as_nanos() as f64 / rows.max(1) as f64,
            p95_ns: p95 * 1e6,
        });
    }
    t.print();
    let speedup = rates[1] / rates[0].max(1e-9);
    println!(
        "\nfleet aggregate speedup over single worker: {} (must be > 1.00x for the \
         sharding to pay for itself)",
        fmt_x(speedup)
    );

    let out = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_fleet.json");
    flashfftconv::bench::write_json(out, &records).expect("write BENCH_fleet.json");
    eprintln!("(wrote {out}: {} records)", records.len());
}
