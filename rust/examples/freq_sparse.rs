//! Frequency-sparse convolutions (Table 9/10 analogue).
//!
//! Sweeps the Table 10 sparsity ladder: for each pattern, reports the
//! modeled matmul-FLOP saving, the *measured* kernel time of the
//! block-skipping sparse artifact, and the model-quality column (loss of
//! the frequency-sparsified LM eval artifacts).
//!
//! ```bash
//! cargo run --release --example freq_sparse
//! ```

use flashfftconv::bench::{workloads, BenchConfig};
use flashfftconv::coordinator::sparse::SparsityPattern;
use flashfftconv::runtime::{HostTensor, Runtime};
use flashfftconv::trainer::data::TokenGen;
use flashfftconv::util::Args;

fn main() -> flashfftconv::Result<()> {
    let args = Args::parse_from(std::env::args().skip(1))?;
    let iters = args.get_usize("iters", 6)?;
    args.finish()?;
    let runtime = Runtime::new("artifacts")?;
    let cfg = BenchConfig { iters, ..BenchConfig::from_env() };

    // --- kernel speedup sweep (conv_sparse artifacts at N=4096) ---
    println!("frequency-sparse kernel sweep (N=4096, order-2 block skipping):");
    println!("{:>6} {:>9} {:>11} {:>10} {:>10}", "tag", "sparsity", "flop_frac", "ms", "speedup");
    let mut base_ms = None;
    for tag in ["s0", "s50", "s75", "s84", "s91", "s94"] {
        let name = format!("conv_sparse_{tag}_n4096");
        let Some(r) = workloads::time_artifact(&runtime, &name, &cfg)? else { continue };
        let spec = runtime.manifest().get(&name)?.clone();
        let (kr, kc) =
            (spec.meta_usize("keep_rows").unwrap(), spec.meta_usize("keep_cols").unwrap());
        let pat = SparsityPattern::new(64, 64, kr, kc)?;
        let ms = r.median_ms();
        let base = *base_ms.get_or_insert(ms);
        println!(
            "{:>6} {:>9.3} {:>11.3} {:>10.2} {:>9.2}x",
            tag,
            pat.sparsity_fraction(),
            pat.flop_fraction(),
            ms,
            base / ms
        );
    }

    // --- quality column (Table 9's PPL row) ---
    println!("\nmodel quality under kernel-spectrum sparsification:");
    println!("{:>22} {:>9} {:>9} {:>7}", "artifact", "sparsity", "loss", "ppl");
    let mut names: Vec<String> = vec!["lm_eval_kmask".into()];
    names.extend(
        runtime.manifest().artifacts.keys().filter(|n| n.starts_with("lm_eval_sparse_")).cloned(),
    );
    for name in names {
        let mut art = runtime.load(&name)?;
        let spec = art.spec().clone();
        let (batch, seq, vocab) = (
            spec.meta_usize("batch").unwrap(),
            spec.meta_usize("seq_len").unwrap(),
            spec.meta_usize("vocab").unwrap(),
        );
        let mut gen = TokenGen::new(vocab, 5);
        let mut total = 0.0;
        let rounds = 4;
        for _ in 0..rounds {
            let tokens = HostTensor::i32(gen.batch(batch, seq + 1), &[batch, seq + 1]);
            let outs = if spec.inputs.iter().any(|i| i.spec.name == "kmask") {
                art.call(&[tokens, HostTensor::f32(vec![1.0; seq], &[seq])])?
            } else {
                art.call(&[tokens])?
            };
            total += outs[0].item();
        }
        let loss = total / rounds as f64;
        println!(
            "{:>22} {:>9} {:>9.4} {:>7.2}",
            name,
            spec.meta("sparsity").unwrap_or("0.0000"),
            loss,
            loss.exp()
        );
    }
    println!(
        "\nTable-9 shape: speedup grows with sparsity while quality stays flat \
         until ~80% of the spectrum is dropped."
    );
    Ok(())
}
