//! Model-serving example: batched LM inference + greedy generation.
//!
//! Starts the [`ModelServer`] over the `lm_fwd_logits` artifact — served
//! by the pure-Rust Hyena zoo engine on the default native backend — then
//! greedy-decodes a continuation of a synthetic prompt and reports the
//! serving statistics. `--shards N` runs N workers behind the fleet
//! dispatcher (`--max-inflight` bounds admission). Run it twice and the
//! generated token ids match: the whole stack is deterministic.
//!
//! ```bash
//! cargo run --release --example serve_model -- --new-tokens 32
//! ```

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use flashfftconv::coordinator::BatchPolicy;
use flashfftconv::ingress::client::IngressClient;
use flashfftconv::ingress::wire::{Reply, Request};
use flashfftconv::ingress::{IngressConfig, IngressServer};
use flashfftconv::runtime::BackendConfig;
use flashfftconv::server::ModelServer;
use flashfftconv::trainer::data::TokenGen;
use flashfftconv::util::Args;
use flashfftconv::zoo::sample::{argmax, greedy_extend};

fn main() -> flashfftconv::Result<()> {
    let args = Args::parse_from(std::env::args().skip(1))?;
    let artifact = args.get("artifact", "lm_fwd_logits");
    let new_tokens = args.get_usize("new-tokens", 32)?;
    let seed = args.get_usize("seed", 1)? as u64;
    let shards = args.get_usize("shards", 1)?;
    let max_inflight = args.get_usize("max-inflight", 64)?;
    args.finish()?;

    let policy = BatchPolicy { batch_size: 4, max_wait: Duration::from_millis(2) };
    let server = Arc::new(ModelServer::start_sharded(
        BackendConfig::Auto("artifacts".into()),
        &artifact,
        policy,
        shards,
        max_inflight,
    )?);
    println!(
        "serving {artifact}: context {} tokens, vocab {} ({shards} shard(s), \
         max_inflight {max_inflight})",
        server.seq_len, server.vocab
    );

    let mut gen = TokenGen::new(server.vocab, seed);
    let prompt = gen.batch(1, server.seq_len);
    let t0 = Instant::now();
    let seq = greedy_extend(&server, &prompt, new_tokens)?;
    let wall = t0.elapsed();

    let generated = &seq[server.seq_len..];
    println!(
        "prompt tail : {:?}",
        &seq[server.seq_len.saturating_sub(8)..server.seq_len]
    );
    println!("generated   : {generated:?}");
    let f = server.fleet().stats();
    println!(
        "{new_tokens} tokens in {:.2}s ({:.1} tok/s)  batches {}  mean latency {:.2} ms  \
         p50 {:.2} ms  p99 {:.2} ms",
        wall.as_secs_f64(),
        new_tokens as f64 / wall.as_secs_f64(),
        f.batches,
        f.mean_latency_ms,
        f.p50_ms,
        f.p99_ms,
    );
    assert_eq!(generated.len(), new_tokens);

    // --- Incremental decode over the TCP ingress --------------------------
    // Same fleet, reached through the wire protocol: full-context logits,
    // then an open_session / step / close_session decode whose tokens must
    // match the in-process greedy decode (the stack stays deterministic
    // through the network boundary).
    // Hardened front: lifecycle deadlines evict stalled peers, a reply
    // deadline bounds every wire round trip.
    let ingress = IngressServer::bind(
        "127.0.0.1:0",
        None,
        Some(Arc::clone(&server)),
        IngressConfig {
            idle_timeout: Some(Duration::from_secs(30)),
            frame_timeout: Some(Duration::from_secs(5)),
            write_timeout: Some(Duration::from_secs(5)),
            reply_deadline: Some(Duration::from_secs(10)),
            ..IngressConfig::default()
        },
    )?;
    let addr = ingress.local_addr();
    println!(
        "\ningress listening on {addr} (wire v{}); decoding over the wire...",
        flashfftconv::ingress::wire::WIRE_VERSION
    );
    let mut client = IngressClient::connect(addr)?;

    let logits = match client.call_retry(
        &Request::LmLogits { tokens: prompt.clone() },
        64,
        Duration::from_millis(1),
    )? {
        Reply::Ok { data, .. } => data,
        other => panic!("lm_logits over the wire failed: {other:?}"),
    };
    assert_eq!(logits.len(), server.vocab);

    let (sid, mut logits) = match client.call_retry(
        &Request::OpenSession { prompt: prompt.clone() },
        64,
        Duration::from_millis(1),
    )? {
        Reply::Ok { session: Some(sid), data, .. } => (sid, data),
        other => panic!("open_session over the wire failed: {other:?}"),
    };
    let mut wire_tokens: Vec<i32> = Vec::new();
    for _ in 0..new_tokens.min(8) {
        let next = argmax(&logits)? as i32;
        wire_tokens.push(next);
        logits = match client.call(&Request::Step { session: sid, token: next })? {
            Reply::Ok { data, .. } => data,
            other => panic!("step over the wire failed: {other:?}"),
        };
    }
    match client.call(&Request::CloseSession { session: sid })? {
        Reply::Ok { .. } => {}
        other => panic!("close_session over the wire failed: {other:?}"),
    }
    client.finish();
    assert_eq!(
        &wire_tokens[..],
        &generated[..wire_tokens.len()],
        "wire decode must match the in-process greedy decode"
    );
    let ist = ingress.stats();
    println!(
        "wire decode : {wire_tokens:?} (matches in-process)  \
         [{} frames in / {} replies out]",
        ist.frames_in.load(Ordering::Relaxed),
        ist.replies_out.load(Ordering::Relaxed),
    );
    // Graceful teardown: the drained sessions were closed above, so this
    // returns as soon as the pool is quiet.
    ingress.shutdown(Duration::from_secs(2));
    println!("ingress drained and shut down");
    Ok(())
}
