//! End-to-end training driver (the DESIGN.md §5 validation workload).
//!
//! Trains the Hyena LM — forward, backward (through the custom-VJP Monarch
//! convolution kernels), and Adam all inside one AOT-compiled HLO module —
//! for a few hundred steps on the synthetic Zipf-Markov corpus, entirely
//! from Rust. Logs the loss curve to CSV and prints a summary.
//!
//! ```bash
//! cargo run --release --example train_lm -- --steps 300
//! ```
//!
//! The default artifact is the `lm_train_monarch` config built by
//! `make artifacts` (scale it up with `python -m compile.aot --lm-dim ...`).

use flashfftconv::runtime::Runtime;
use flashfftconv::trainer::run::Budget;
use flashfftconv::trainer::{TrainConfig, Trainer};
use flashfftconv::util::Args;

fn main() -> flashfftconv::Result<()> {
    let args = Args::parse_from(std::env::args().skip(1))?;
    let steps = args.get_usize("steps", 300)? as u64;
    let artifact = args.get("artifact", "lm_train_monarch");
    let csv = args.get("loss-csv", "train_lm_loss.csv");
    args.finish()?;

    let runtime = Runtime::new("artifacts")?;
    let mut trainer = Trainer::new(
        &runtime,
        TrainConfig {
            artifact: artifact.clone(),
            budget: Budget::Steps(steps),
            log_every: 25,
            seed: 0,
            checkpoint: Some("train_lm.ckpt".into()),
        },
    )?;
    let params = trainer.artifact().spec().meta_usize("n_params").unwrap_or(0);
    println!(
        "training {artifact} ({params} params, {} tokens/step) for {steps} steps...",
        trainer.tokens_per_step()
    );
    let o = trainer.run()?;
    o.log.write_csv(&csv)?;
    println!(
        "\nloss {:.4} -> {:.4} (ppl {:.2} -> {:.2}) in {:.1}s  [{:.0} tok/s]",
        o.first_loss,
        o.final_loss,
        o.first_loss.exp(),
        o.final_loss.exp(),
        o.elapsed.as_secs_f64(),
        o.log.tokens_per_sec()
    );
    println!("{}", o.log.sparkline(72));
    println!("loss curve -> {csv}; checkpoint -> train_lm.ckpt");
    assert!(o.final_loss < o.first_loss, "training must reduce the loss");
    Ok(())
}
