//! Quickstart: load a FlashFFTConv artifact, run a convolution, verify it.
//!
//! ```bash
//! cargo run --release --example quickstart          # native CPU backend
//! make artifacts && cargo run --release --example quickstart  # pjrt build
//! ```
//!
//! Demonstrates the full public API surface in ~60 lines: open the
//! [`Runtime`] over the artifact directory, load the fused Monarch conv
//! for N=1024, run it on random data, and check the result against both
//! the recorded JAX golden output and the crate's native FFT oracle.

use flashfftconv::fft;
use flashfftconv::runtime::{golden, HostTensor, Runtime};
use flashfftconv::util::Rng;

fn main() -> flashfftconv::Result<()> {
    let runtime = Runtime::new("artifacts")?;
    let name = "conv_fwd_monarch_n1024";
    let mut conv = runtime.load(name)?;
    let spec = conv.spec().clone();
    let (b, h, n) = (
        spec.meta_usize("batch").unwrap(),
        spec.meta_usize("heads").unwrap(),
        spec.meta_usize("seq_len").unwrap(),
    );
    println!(
        "loaded {name}: B={b} H={h} N={n} (order-{} Monarch, r2c packed)",
        spec.meta("order").unwrap_or("2")
    );

    // 1. Replay the recorded golden transcript (reference path vs this
    //    engine: the radix-2 oracle natively, python JAX under pjrt).
    let g = golden::load(&runtime, &spec)?.expect("golden transcript");
    let outs = conv.call(&g.inputs)?;
    let err = outs[0].max_abs_diff(&g.outputs[0]);
    println!("golden replay: max|err| = {err:.2e}");
    assert!(err < 2e-3);

    // 2. Fresh random convolution, verified against the native FFT oracle.
    let mut rng = Rng::new(42);
    let u: Vec<f32> = rng.normal_vec(b * h * n);
    let k: Vec<f32> = rng.normal_vec(h * n);
    let outs = conv.call(&[
        HostTensor::f32(u.clone(), &[b, h, n]),
        HostTensor::f32(k.clone(), &[h, n]),
    ])?;
    let y = outs[0].as_f32();

    let mut worst = 0.0f64;
    for bi in 0..b {
        for hi in 0..h {
            let urow: Vec<f64> =
                u[(bi * h + hi) * n..(bi * h + hi + 1) * n].iter().map(|&x| x as f64).collect();
            let krow: Vec<f64> = k[hi * n..(hi + 1) * n].iter().map(|&x| x as f64).collect();
            let want = fft::fft_conv(&urow, &krow);
            for (g_, w) in y[(bi * h + hi) * n..(bi * h + hi + 1) * n].iter().zip(&want) {
                worst = worst.max((*g_ as f64 - w).abs());
            }
        }
    }
    println!("oracle check over {b}x{h} sequences: max|err| = {worst:.2e}");
    assert!(worst < 1e-2);
    println!("quickstart OK");
    Ok(())
}
