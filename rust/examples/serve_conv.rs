//! Serving example: the sharded convolution fleet.
//!
//! Spins up a [`ConvService`] over N shard workers (router -> dynamic
//! batcher -> fused artifact per worker thread, one dispatcher with
//! bounded admission in front), installs a filter bank, submits a stream
//! of mixed-length requests from several client threads, and reports
//! latency / throughput / batching / backpressure statistics.
//!
//! ```bash
//! cargo run --release --example serve_conv -- --requests 64 --shards 2
//! ```

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use flashfftconv::coordinator::fleet::LatencyHistogram;
use flashfftconv::coordinator::router::ConvKind;
use flashfftconv::coordinator::service::{ConvRequest, ConvService};
use flashfftconv::coordinator::BatchPolicy;
use flashfftconv::ingress::client::IngressClient;
use flashfftconv::ingress::wire::{Reply, Request};
use flashfftconv::ingress::{IngressConfig, IngressServer};
use flashfftconv::runtime::BackendConfig;
use flashfftconv::util::{Args, Rng};

fn main() -> flashfftconv::Result<()> {
    let args = Args::parse_from(std::env::args().skip(1))?;
    let requests = args.get_usize("requests", 64)?;
    let clients = args.get_usize("clients", 4)?;
    let shards = args.get_usize("shards", 2)?;
    let max_inflight = args.get_usize("max-inflight", 128)?;
    let variant = args.get("variant", "monarch");
    args.finish()?;

    let policy = BatchPolicy { batch_size: 2, max_wait: Duration::from_millis(4) };
    let service = Arc::new(ConvService::start_sharded(
        BackendConfig::Auto("artifacts".into()),
        &variant,
        policy,
        shards,
        max_inflight,
    )?);
    let heads = 16usize;

    // Pretend-pretrained filter banks for two buckets, broadcast to every
    // shard (and replayed onto any shard the supervisor respawns).
    let mut rng = Rng::new(9);
    for bucket in [256usize, 1024] {
        service.set_filter(ConvKind::Forward, bucket, rng.normal_vec(heads * bucket))?;
    }

    // Warm up: the first request per (shard, bucket) pays artifact
    // compile; exclude it from the serving statistics (steady-state is
    // what Table 5 reports). A concurrent burst per bucket is what
    // reaches every shard — sequential calls at zero outstanding would
    // always pick the bucket's affinity shard.
    for bucket in [256usize, 1000] {
        let pending: Vec<_> = (0..2 * shards.max(1))
            .map(|_| {
                let u = rng.normal_vec(heads * bucket);
                service
                    .fleet()
                    .submit_blocking(ConvRequest {
                        kind: ConvKind::Forward,
                        len: bucket,
                        streams: vec![u], chunk_tx: None
                    })
                    .expect("warmup admitted")
            })
            .collect();
        for rx in pending {
            rx.recv().expect("fleet alive").expect("warmup conv ok");
        }
    }
    let warm = service.fleet().stats();
    let warm_counts = service.fleet().latency_counts();
    println!("(warmup: {} requests, compile included)", warm.requests);

    println!(
        "serving {requests} requests from {clients} clients across {shards} shards \
         ({variant} kernels, max_inflight {max_inflight})..."
    );
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for c in 0..clients {
            let service = &service;
            s.spawn(move || {
                let mut rng = Rng::new(100 + c as u64);
                let per_client = requests / clients.max(1);
                let mut pending = Vec::with_capacity(per_client);
                for i in 0..per_client {
                    // Mixed lengths: exercise routing + padding.
                    let len = if (i + c) % 3 == 0 { 1000 } else { 256 };
                    let u = rng.normal_vec(heads * len);
                    let req =
                        ConvRequest { kind: ConvKind::Forward, len, streams: vec![u], chunk_tx: None };
                    // Bounded admission: block until the fleet admits
                    // (backpressure without a spin loop).
                    let rx = service
                        .fleet()
                        .submit_blocking(req)
                        .expect("fleet admits");
                    pending.push(rx);
                }
                for rx in pending {
                    rx.recv().expect("fleet alive").expect("conv ok");
                }
            });
        }
    });
    let wall = t0.elapsed();

    let f = service.fleet().stats();
    let served = f.rows_executed - warm.rows_executed;
    // Steady-state quantiles: diff the latency histogram around the
    // serving window so warmup compile spikes are excluded.
    let mut window = service.fleet().latency_counts();
    for (w, b) in window.iter_mut().zip(warm_counts.iter()) {
        *w -= b;
    }
    println!(
        "\nserved {served} rows in {:.2}s  ({:.1} rows/s)\n\
         batches          : {}\n\
         mean occupancy   : {:.2} rows/batch\n\
         latency p50/p99  : {:.2} / {:.2} ms (steady state)\n\
         busy rejections  : {}\n\
         deaths/restarts  : {} / {}\n\
         errors           : {}",
        wall.as_secs_f64(),
        served as f64 / wall.as_secs_f64(),
        f.batches,
        f.mean_occupancy,
        LatencyHistogram::quantile_ms(&window, 0.50),
        LatencyHistogram::quantile_ms(&window, 0.99),
        f.busy_rejections,
        f.shard_deaths,
        f.restarts,
        f.errors,
    );
    for s in &f.shards {
        println!("  {}", s.summary());
    }

    // --- The same fleet behind the TCP ingress ---------------------------
    // Bind the wire-framed front on an ephemeral loopback port and drive
    // it with real TCP clients, including a live filter install over the
    // wire (two-phase epoch swap, acked with the visible epoch). The
    // config is the hardened deployment shape: lifecycle deadlines so a
    // stalled peer cannot pin a pool slot, and a reply deadline so no
    // request outlives its usefulness on the wire.
    let ingress = IngressServer::bind(
        "127.0.0.1:0",
        Some(Arc::clone(&service)),
        None,
        IngressConfig {
            idle_timeout: Some(Duration::from_secs(30)),
            frame_timeout: Some(Duration::from_secs(5)),
            write_timeout: Some(Duration::from_secs(5)),
            reply_deadline: Some(Duration::from_secs(10)),
            ..IngressConfig::default()
        },
    )?;
    let addr = ingress.local_addr();
    println!(
        "\ningress listening on {addr} (wire v{}); driving {clients} TCP clients...",
        flashfftconv::ingress::wire::WIRE_VERSION
    );
    std::thread::scope(|s| {
        for c in 0..clients {
            s.spawn(move || {
                let mut rng = Rng::new(500 + c as u64);
                let mut client = IngressClient::connect(addr).expect("client connects");
                for i in 0..4usize {
                    let len = if (i + c) % 3 == 0 { 1000usize } else { 256 };
                    let u = rng.normal_vec(heads * len);
                    let req = Request::Conv { kind: 0, len: len as u32, streams: vec![u] };
                    match client
                        .call_retry(&req, 64, Duration::from_millis(1))
                        .expect("wire round trip")
                    {
                        Reply::Ok { data, .. } => assert_eq!(data.len(), heads * len),
                        other => panic!("unexpected wire reply: {other:?}"),
                    }
                }
                client.finish();
            });
        }
    });
    let mut client = IngressClient::connect(addr)?;
    let taps = rng.normal_vec(heads * 256);
    let epoch = match client.call(&Request::InstallFilter { kind: 0, bucket: 256, taps })? {
        Reply::Ok { epoch, .. } => epoch,
        other => panic!("filter install over the wire failed: {other:?}"),
    };
    client.finish();
    let ist = ingress.stats();
    println!(
        "ingress: {} connections, {} frames in, {} replies out, {} busy; \
         filter swap visible at epoch {epoch}",
        ist.accepted.load(Ordering::Relaxed),
        ist.frames_in.load(Ordering::Relaxed),
        ist.replies_out.load(Ordering::Relaxed),
        ist.busy_replies.load(Ordering::Relaxed),
    );
    // Graceful teardown: drain in-flight replies before closing.
    ingress.shutdown(Duration::from_secs(2));
    println!("ingress drained and shut down");
    Ok(())
}
