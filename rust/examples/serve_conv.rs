//! Serving example: the coordinator's batched convolution service.
//!
//! Spins up the [`ConvService`] (router -> dynamic batcher -> fused
//! artifact on a dedicated PJRT thread), installs a filter bank, submits a
//! stream of mixed-length requests from several client threads, and
//! reports latency / throughput / batching statistics.
//!
//! ```bash
//! cargo run --release --example serve_conv -- --requests 64
//! ```

use std::sync::atomic::Ordering;
use std::time::{Duration, Instant};

use flashfftconv::coordinator::router::ConvKind;
use flashfftconv::coordinator::service::{ConvRequest, ConvService};
use flashfftconv::coordinator::BatchPolicy;
use flashfftconv::runtime::BackendConfig;
use flashfftconv::util::{Args, Rng};

fn main() -> flashfftconv::Result<()> {
    let args = Args::parse_from(std::env::args().skip(1))?;
    let requests = args.get_usize("requests", 64)?;
    let clients = args.get_usize("clients", 4)?;
    let variant = args.get("variant", "monarch");
    args.finish()?;

    let policy = BatchPolicy { batch_size: 2, max_wait: Duration::from_millis(4) };
    let service = ConvService::start(BackendConfig::Auto("artifacts".into()), &variant, policy)?;
    let heads = 16usize;

    // Pretend-pretrained filter banks for two buckets.
    let mut rng = Rng::new(9);
    for bucket in [256usize, 1024] {
        service.set_filter(ConvKind::Forward, bucket, rng.normal_vec(heads * bucket))?;
    }

    // Warm up: first request per bucket pays artifact compile; exclude it
    // from the serving statistics (steady-state is what Table 5 reports).
    for bucket in [256usize, 1000] {
        let u = rng.normal_vec(heads * bucket);
        service
            .call(ConvRequest { kind: ConvKind::Forward, len: bucket, streams: vec![u] })?;
    }
    let warm_reqs = service.stats().requests.load(Ordering::Relaxed);
    let warm_lat = service.stats().latency_ns_sum.load(Ordering::Relaxed);
    println!("(warmup: {warm_reqs} requests, compile included)");

    println!("serving {requests} requests from {clients} clients ({variant} kernels)...");
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for c in 0..clients {
            let service = &service;
            s.spawn(move || {
                let mut rng = Rng::new(100 + c as u64);
                let per_client = requests / clients;
                let mut pending = Vec::with_capacity(per_client);
                for i in 0..per_client {
                    // Mixed lengths: exercise routing + padding.
                    let len = if (i + c) % 3 == 0 { 1000 } else { 256 };
                    let u = rng.normal_vec(heads * len);
                    pending.push(service.submit(ConvRequest {
                        kind: ConvKind::Forward,
                        len,
                        streams: vec![u],
                    }));
                }
                for rx in pending {
                    rx.recv().expect("service alive").expect("conv ok");
                }
            });
        }
    });
    let wall = t0.elapsed();

    let s = service.stats();
    let served = s.rows_executed.load(Ordering::Relaxed) - warm_reqs;
    let steady_reqs = s.requests.load(Ordering::Relaxed) - warm_reqs;
    let steady_lat =
        (s.latency_ns_sum.load(Ordering::Relaxed) - warm_lat) as f64 / steady_reqs as f64 / 1e6;
    println!(
        "\nserved {served} rows in {:.2}s  ({:.1} rows/s)\n\
         batches          : {}\n\
         mean occupancy   : {:.2} rows/batch\n\
         mean latency     : {:.2} ms (steady state)\n\
         max latency      : {:.2} ms (includes queueing)\n\
         errors           : {}",
        wall.as_secs_f64(),
        served as f64 / wall.as_secs_f64(),
        s.batches.load(Ordering::Relaxed),
        s.mean_occupancy(),
        steady_lat,
        s.latency_ns_max.load(Ordering::Relaxed) as f64 / 1e6,
        s.errors.load(Ordering::Relaxed),
    );
    Ok(())
}
