//! Partial convolutions for sequence-length extension (Table 8 analogue).
//!
//! The paper extends HyenaDNA from 1M to 4M tokens by sliding its (partial
//! convolution) context window over the longer sequence. This example
//! reproduces the workflow at testbed scale:
//!
//! 1. briefly pretrain the DNA model (context 4096, filter length 1024 —
//!    a *partial* convolution) on synthetic DNA with long-range motifs;
//! 2. copy the trained parameters into the evaluation artifact;
//! 3. evaluate sequences 2x/4x longer than the training context with
//!    the coordinator's sliding-window extension plan;
//! 4. report PPL per length — flat PPL across lengths is the paper's
//!    Table 8 result shape.
//!
//! ```bash
//! cargo run --release --example dna_extend -- --train-steps 60
//! ```

use flashfftconv::coordinator::partial::ExtensionPlan;
use flashfftconv::runtime::{HostTensor, Runtime};
use flashfftconv::trainer::data::DnaGen;
use flashfftconv::trainer::run::Budget;
use flashfftconv::trainer::{TrainConfig, Trainer};
use flashfftconv::util::Args;

fn main() -> flashfftconv::Result<()> {
    let args = Args::parse_from(std::env::args().skip(1))?;
    let train_steps = args.get_usize("train-steps", 60)? as u64;
    let factors = args.get_usize_list("extend-factors", &[1, 2, 4])?;
    args.finish()?;

    let runtime = Runtime::new("artifacts")?;

    // 1. Pretrain briefly.
    println!("pretraining dna model ({train_steps} steps)...");
    let mut trainer = Trainer::new(
        &runtime,
        TrainConfig {
            artifact: "dna_train".into(),
            budget: Budget::Steps(train_steps),
            log_every: 20,
            seed: 1,
            checkpoint: None,
        },
    )?;
    let o = trainer.run()?;
    println!("  train loss {:.4} -> {:.4}", o.first_loss, o.final_loss);

    // 2. Copy trained params into the eval artifact.
    let mut eval = runtime.load("dna_eval")?;
    let names: Vec<String> = eval
        .spec()
        .inputs
        .iter()
        .filter(|i| i.spec.name.starts_with("param."))
        .map(|i| i.spec.name.clone())
        .collect();
    for name in &names {
        let t = trainer.artifact().state(name)?;
        eval.set_operand(name, &t)?;
    }
    println!("  copied {} trained parameter tensors into dna_eval", names.len());

    // 3/4. Sliding-window extension.
    let spec = eval.spec().clone();
    let context = spec.meta_usize("seq_len").unwrap();
    let kmask_len =
        spec.inputs.iter().find(|i| i.spec.name == "kmask").map(|i| i.spec.numel()).unwrap();
    let mask = vec![1.0f32; kmask_len];
    println!("\ncontext {context}, filter length {kmask_len} (partial conv)");
    println!("{:>10}  {:>8}  {:>7}  {:>7}", "total_len", "windows", "loss", "ppl");
    for f in factors {
        let total = context * f.max(1);
        let plan = ExtensionPlan::new(total, context, context / 2)?;
        let mut gen = DnaGen::new(64, 7); // same data distribution per row
        let seq = gen.sequence(total + 1);
        let mut losses = vec![];
        for w in &plan.windows {
            let window: Vec<i32> = seq[w.start..w.start + context + 1].to_vec();
            let outs = eval.call(&[
                HostTensor::i32(window, &[1, context + 1]),
                HostTensor::f32(mask.clone(), &[kmask_len]),
            ])?;
            losses.push(outs[0].item());
        }
        let loss = plan.combine_losses(&losses);
        println!("{:>10}  {:>8}  {:>7.4}  {:>7.3}", total, plan.calls(), loss, loss.exp());
    }
    println!(
        "\nTable-8 shape: PPL stays ~flat as the evaluated sequence grows past the \
         training context — the partial-conv window extends the model for free."
    );
    Ok(())
}
