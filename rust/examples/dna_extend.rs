//! Partial convolutions for sequence-length extension (Table 8 analogue).
//!
//! The paper extends HyenaDNA from 1M to 4M tokens by sliding its (partial
//! convolution) context window over the longer sequence. This example
//! reproduces the workflow at testbed scale:
//!
//! 1. briefly pretrain the DNA model (context 4096, filter length 1024 —
//!    a *partial* convolution) on synthetic DNA with long-range motifs;
//! 2. copy the trained parameters into the evaluation artifact;
//! 3. evaluate sequences 2x/4x longer than the training context with
//!    the coordinator's sliding-window extension plan;
//! 4. report PPL per length — flat PPL across lengths is the paper's
//!    Table 8 result shape;
//! 5. serve a genome-length (default 2.3M bp) causal partial conv end to
//!    end: the same sharded fleet + TCP ingress as `serve --listen`, with
//!    a `NativeLongConv` bucket chunking the conv through a fixed
//!    workspace budget and the wire streaming every chunk as an
//!    `ok_chunk` frame the moment it is computed — the genome stays
//!    resident, the scratch does not, and the client holds one chunk at
//!    a time.
//!
//! ```bash
//! cargo run --release --example dna_extend -- --train-steps 60 --genome-len 2300000
//! ```

use std::sync::Arc;
use std::time::Duration;

use flashfftconv::coordinator::partial::ExtensionPlan;
use flashfftconv::coordinator::router::ConvKind;
use flashfftconv::coordinator::service::{ConvRequest, ConvService};
use flashfftconv::coordinator::BatchPolicy;
use flashfftconv::fft::chunked::chunk_scratch_bytes;
use flashfftconv::format_err;
use flashfftconv::ingress::client::IngressClient;
use flashfftconv::ingress::wire::{Reply, Request};
use flashfftconv::ingress::{IngressConfig, IngressServer};
use flashfftconv::runtime::{BackendConfig, HostTensor, Runtime};
use flashfftconv::trainer::data::DnaGen;
use flashfftconv::trainer::run::Budget;
use flashfftconv::trainer::{TrainConfig, Trainer};
use flashfftconv::util::{Args, Rng};

fn main() -> flashfftconv::Result<()> {
    let args = Args::parse_from(std::env::args().skip(1))?;
    let train_steps = args.get_usize("train-steps", 60)? as u64;
    let factors = args.get_usize_list("extend-factors", &[1, 2, 4])?;
    let genome_len = args.get_usize("genome-len", 2_300_000)?;
    args.finish()?;

    let runtime = Runtime::new("artifacts")?;

    // 1. Pretrain briefly.
    println!("pretraining dna model ({train_steps} steps)...");
    let mut trainer = Trainer::new(
        &runtime,
        TrainConfig {
            artifact: "dna_train".into(),
            budget: Budget::Steps(train_steps),
            log_every: 20,
            seed: 1,
            checkpoint: None,
        },
    )?;
    let o = trainer.run()?;
    println!("  train loss {:.4} -> {:.4}", o.first_loss, o.final_loss);

    // 2. Copy trained params into the eval artifact.
    let mut eval = runtime.load("dna_eval")?;
    let names: Vec<String> = eval
        .spec()
        .inputs
        .iter()
        .filter(|i| i.spec.name.starts_with("param."))
        .map(|i| i.spec.name.clone())
        .collect();
    for name in &names {
        let t = trainer.artifact().state(name)?;
        eval.set_operand(name, &t)?;
    }
    println!("  copied {} trained parameter tensors into dna_eval", names.len());

    // 3/4. Sliding-window extension.
    let spec = eval.spec().clone();
    let context = spec.meta_usize("seq_len").unwrap();
    let kmask_len =
        spec.inputs.iter().find(|i| i.spec.name == "kmask").map(|i| i.spec.numel()).unwrap();
    let mask = vec![1.0f32; kmask_len];
    println!("\ncontext {context}, filter length {kmask_len} (partial conv)");
    println!("{:>10}  {:>8}  {:>7}  {:>7}", "total_len", "windows", "loss", "ppl");
    for f in factors {
        let total = context * f.max(1);
        let plan = ExtensionPlan::new(total, context, context / 2)?;
        let mut gen = DnaGen::new(64, 7); // same data distribution per row
        let seq = gen.sequence(total + 1);
        let mut losses = vec![];
        for w in &plan.windows {
            let window: Vec<i32> = seq[w.start..w.start + context + 1].to_vec();
            let outs = eval.call(&[
                HostTensor::i32(window, &[1, context + 1]),
                HostTensor::f32(mask.clone(), &[kmask_len]),
            ])?;
            losses.push(outs[0].item());
        }
        let loss = plan.combine_losses(&losses);
        println!("{:>10}  {:>8}  {:>7.4}  {:>7.3}", total, plan.calls(), loss, loss.exp());
    }
    println!(
        "\nTable-8 shape: PPL stays ~flat as the evaluated sequence grows past the \
         training context — the partial-conv window extends the model for free."
    );

    // 5. Genome-length serving through the fleet and the wire.
    serve_genome(genome_len)
}

/// Serve one `n`-base-pair causal partial conv end to end: long-conv
/// bucket (chunked overlap-add under a workspace budget) behind the TCP
/// ingress, filter installed over the wire with the canonical retry
/// loop, reply consumed chunk-by-chunk as frames land. Asserts the
/// streamed result is bitwise identical to an in-process run through the
/// same engine and spot-checks it against the direct O(N*L) definition.
fn serve_genome(n: usize) -> flashfftconv::Result<()> {
    let lk = 1024usize;
    // Budget sized for a 16K chunk: the genome stays resident, the FFT
    // scratch does not — peak workspace is O(chunk), not O(n).
    let budget = chunk_scratch_bytes(2 * 16384, 1);
    println!(
        "\nserving a {n}-bp genome conv ({lk} taps) through the fleet, \
         workspace budget {} KB...",
        budget / 1024
    );

    let service = Arc::new(
        ConvService::start_sharded(
            BackendConfig::NativeLongConv { n, filter_len: lk, budget_bytes: budget },
            "monarch",
            BatchPolicy { batch_size: 1, max_wait: Duration::from_millis(1) },
            1,
            16,
        )?,
    );
    let ingress = IngressServer::bind(
        "127.0.0.1:0",
        Some(service.clone()),
        None,
        IngressConfig { stream_chunk_points: 1 << 16, ..IngressConfig::default() },
    )?;
    let mut client = IngressClient::connect(ingress.local_addr())?;

    // The genome: DNA bases centered to a +/-0.75 signal, with the
    // generator's long-range motif structure intact.
    let mut gen = DnaGen::new(64, 11);
    let u: Vec<f32> = gen.sequence(n).into_iter().map(|t| (t as f32 - 1.5) * 0.5).collect();
    // A causal motif-detector filter: random taps under a decay envelope.
    let mut rng = Rng::new(0x6E0);
    let k: Vec<f32> = (0..lk)
        .map(|j| {
            let decay = (-(j as f64) / 256.0).exp() as f32;
            rng.normal() as f32 * decay
        })
        .collect();

    // Two-phase filter install over the wire (kind 2 = causal), with the
    // canonical capped-backoff retry loop.
    let reply = client.call_retry(
        &Request::InstallFilter { kind: 2, bucket: n as u32, taps: k.clone() },
        5,
        Duration::from_millis(10),
    )?;
    let Reply::Ok { epoch: installed, .. } = reply else {
        return Err(format_err!("filter install failed: {reply:?}"));
    };

    // In-process reference through the very same engine.
    let rx = service
        .fleet()
        .submit(ConvRequest {
            kind: ConvKind::Causal,
            len: n,
            streams: vec![u.clone()],
            chunk_tx: None,
        })
        .map_err(|e| format_err!("in-process submit rejected: {e:?}"))?;
    let want = rx
        .recv()
        .map_err(|_| format_err!("in-process reply slot dropped"))?
        .map_err(|e| format_err!("in-process conv failed: {e:?}"))?;

    // The same request over TCP, consumed chunk-by-chunk as frames land.
    let id = client.send(&Request::Conv { kind: 2, len: n as u32, streams: vec![u.clone()] })?;
    let mut streamed: Vec<f32> = Vec::with_capacity(n);
    let mut frames = 0usize;
    let (rid, reply) = client.recv_chunks(|part| {
        frames += 1;
        streamed.extend_from_slice(part);
        Ok(())
    })?;
    let Reply::Ok { epoch: served, .. } = reply else {
        return Err(format_err!("genome conv failed over the wire: {reply:?}"));
    };
    assert_eq!(rid, id);
    assert_eq!(served, installed, "served epoch must be the installed filter's");
    assert_eq!(streamed.len(), n, "streamed chunks must cover the whole genome");
    if n >= IngressConfig::default().stream_conv_threshold_points {
        assert!(frames > 1, "a genome-length reply must arrive as many live chunks");
    }
    for (i, (a, b)) in streamed.iter().zip(&want.data).enumerate() {
        assert!(
            a.to_bits() == b.to_bits(),
            "wire/in-process bit mismatch at bp {i}: {a:e} vs {b:e}"
        );
    }

    // Spot-check sampled loci against the direct causal-conv definition
    // (f64 accumulation): y[t] = sum_{j<L} k[j] * u[t-j].
    let mut worst = 0.0f64;
    for t in (0..n).step_by(n / 37 + 1) {
        let mut acc = 0.0f64;
        for j in 0..lk.min(t + 1) {
            acc += k[j] as f64 * u[t - j] as f64;
        }
        worst = worst.max((streamed[t] as f64 - acc).abs());
    }
    assert!(worst < 1e-3, "direct-definition divergence {worst}");

    let peak = service.fleet().stats().workspace_peak_bytes;
    assert!(
        peak <= budget,
        "measured workspace peak {peak} must respect the {budget}-byte budget"
    );
    println!(
        "  {n} bp served bitwise-identical to in-process in {frames} wire chunks; \
         worst sampled |err| vs direct definition {worst:.2e}; \
         workspace peak {} KB <= budget {} KB",
        peak / 1024,
        budget / 1024
    );
    Ok(())
}
