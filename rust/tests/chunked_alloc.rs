//! Counting-allocator proof of the chunked-streaming budget contract:
//! once the workspace is warm, streaming a genome-scale conv through a
//! `ChunkedConvPlan` touches the heap zero times, and the measured
//! workspace peak stays under both the plan's own `scratch_bytes()`
//! estimate and the byte budget the chunk size was picked for
//! ("estimate <= budget => measured peak <= budget").
//!
//! This binary installs a counting global allocator, so it deliberately
//! holds exactly one `#[test]`: concurrent test threads in the same
//! binary would pollute the allocation counters.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use flashfftconv::fft::chunked::{chunk_scratch_bytes, pick_chunk, ChunkedConvPlan};
use flashfftconv::fft::workspace::ConvWorkspace;
use flashfftconv::util::Rng;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::SeqCst);
        System.alloc(l)
    }
    unsafe fn alloc_zeroed(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::SeqCst);
        System.alloc_zeroed(l)
    }
    unsafe fn realloc(&self, p: *mut u8, l: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::SeqCst);
        System.realloc(p, l, new_size)
    }
    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
}

#[global_allocator]
static A: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOCS.load(Ordering::SeqCst)
}

#[test]
fn chunked_streaming_is_zero_alloc_and_respects_the_budget() {
    let mut rng = Rng::new(0xD0A);
    // A signal ~50x longer than the chunk the budget allows: the whole
    // point is that peak scratch depends on C, not N.
    let n = 200_000usize;
    let l = 129usize;
    let budget = chunk_scratch_bytes(2 * 2048, 1);
    let chunk = pick_chunk(n, l, budget, 1).expect("budget admits a chunk");
    assert!(
        chunk_scratch_bytes(2 * chunk, 1) <= budget,
        "pick_chunk must honor the budget (chunk {chunk}, budget {budget})"
    );
    // Order pinned so the measured loop exercises no autotuner state.
    let plan = ChunkedConvPlan::with_order(n, l, chunk, Some(2)).expect("plan builds");
    assert!(plan.scratch_bytes() <= budget, "estimate must fit the budget");

    let u32v: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
    let k: Vec<f64> = (0..l).map(|_| rng.normal()).collect();
    let (kre, kim) = plan.filter_spectrum(&k).expect("spectrum");

    // Sink buffer owned by the test: the emit callback narrows into it
    // by index, so the measured loop can't allocate through the sink.
    let mut out = vec![0.0f32; n];
    let mut ws = ConvWorkspace::new();
    let mut run = |ws: &mut ConvWorkspace, out: &mut [f32]| {
        let mut off = 0usize;
        plan.conv_stream_f32(&u32v, &kre, &kim, ws, |part| {
            for (dst, &src) in out[off..off + part.len()].iter_mut().zip(part) {
                *dst = src as f32;
            }
            off += part.len();
            Ok(())
        })
        .expect("stream");
        assert_eq!(off, n, "emitted slices must cover exactly N");
    };

    // Warm pass: cold misses populate the workspace free lists.
    run(&mut ws, &mut out);
    ws.reset();

    let before = allocs();
    for _ in 0..3 {
        run(&mut ws, &mut out);
    }
    let delta = allocs() - before;
    let stats = ws.stats();
    assert_eq!(
        delta, 0,
        "steady-state chunked streaming must perform zero heap allocations \
         (counted {delta} over 3 passes; workspace stats {stats:?})"
    );
    assert_eq!(stats.allocs, 0, "no cold misses after warm-up: {stats:?}");
    assert!(
        stats.peak_bytes <= plan.scratch_bytes(),
        "measured peak {} must stay under the plan estimate {}",
        stats.peak_bytes,
        plan.scratch_bytes()
    );
    assert!(
        stats.peak_bytes <= budget,
        "measured peak {} must stay under the byte budget {budget}",
        stats.peak_bytes
    );

    // The budget can also be *imposed* after the fact: trim() releases
    // cached buffers down to the cap and the next pass still runs.
    ws.trim(budget / 2);
    run(&mut ws, &mut out);
    assert!(out.iter().any(|&v| v != 0.0), "stream actually computed something");
}
