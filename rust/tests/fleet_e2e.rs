//! Fleet end-to-end tests: concurrent-client soak over a 4-shard
//! dispatcher (zero lost replies, outputs bitwise-equal to a direct
//! single-worker `ConvService`, statistics that reconcile with the
//! client-side counts), backpressure exactness, blocking admission, and
//! the ModelServer silent-drop regression.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::Receiver;
use std::time::{Duration, Instant};

use flashfftconv::coordinator::fleet::{FleetConfig, FleetDispatcher, FleetError, FleetReply};
use flashfftconv::coordinator::router::ConvKind;
use flashfftconv::coordinator::service::{ConvProfile, ConvRequest, ConvService};
use flashfftconv::coordinator::BatchPolicy;
use flashfftconv::runtime::BackendConfig;
use flashfftconv::server::{InferRequest, ModelServer};
use flashfftconv::util::Rng;

const HEADS: usize = 16;

fn conv_fleet(
    shards: usize,
    max_inflight: usize,
    batch_size: usize,
    wait_ms: u64,
) -> FleetDispatcher<ConvProfile> {
    FleetDispatcher::conv(
        BackendConfig::NativeRowThreads(1),
        "monarch",
        FleetConfig {
            shards,
            max_inflight,
            policy: BatchPolicy {
                batch_size,
                max_wait: Duration::from_millis(wait_ms),
            },
        },
    )
    .expect("fleet starts")
}

fn forward(len: usize, u: Vec<f32>) -> ConvRequest {
    ConvRequest { kind: ConvKind::Forward, len, streams: vec![u], chunk_tx: None }
}

/// The soak workload's request length for client `c`, request `i`:
/// mostly the 256 bucket (some padded), every 4th in the 1024 bucket.
fn soak_len(c: usize, i: usize) -> usize {
    match (c + i) % 4 {
        0 => 1024,
        1 => 200, // pads into 256
        _ => 256,
    }
}

#[test]
fn soak_concurrent_clients_bitwise_parity_and_reconciled_stats() {
    const CLIENTS: usize = 8;
    const PER_CLIENT: usize = 64;

    let fleet = conv_fleet(4, 64, 2, 2);
    let single = ConvService::start(
        BackendConfig::Native,
        "monarch",
        BatchPolicy { batch_size: 2, max_wait: Duration::from_millis(1) },
    )
    .expect("reference service starts");

    // Identical filter banks on both sides (broadcast to all 4 shards).
    let mut rng = Rng::new(4242);
    for bucket in [256usize, 1024] {
        let k = rng.normal_vec(HEADS * bucket);
        fleet
            .control(flashfftconv::coordinator::service::ConvControl::SetFilter {
                kind: ConvKind::Forward,
                bucket,
                k: k.clone(),
            })
            .expect("fleet filter installs");
        single.set_filter(ConvKind::Forward, bucket, k).expect("single filter installs");
    }

    let busy_total = AtomicU64::new(0);
    let replies_total = AtomicU64::new(0);
    std::thread::scope(|s| {
        for c in 0..CLIENTS {
            let fleet = &fleet;
            let single = &single;
            let busy_total = &busy_total;
            let replies_total = &replies_total;
            s.spawn(move || {
                let mut rng = Rng::new(9_000 + c as u64);
                let mut pending: Vec<(usize, Vec<f32>, Receiver<FleetReply>)> = vec![];
                let mut done: Vec<(usize, Vec<f32>, Vec<f32>)> = vec![];
                for i in 0..PER_CLIENT {
                    let len = soak_len(c, i);
                    let u = rng.normal_vec(HEADS * len);
                    let mut req = forward(len, u.clone());
                    loop {
                        match fleet.try_submit(req) {
                            Ok(rx) => {
                                pending.push((len, u.clone(), rx));
                                break;
                            }
                            Err((r, FleetError::Busy)) => {
                                req = r;
                                busy_total.fetch_add(1, Ordering::Relaxed);
                                // Drain one of our own to free a slot.
                                match pending.pop() {
                                    Some((len, u, rx)) => {
                                        let y = rx
                                            .recv()
                                            .expect("no lost replies")
                                            .expect("conv ok")
                                            .data;
                                        done.push((len, u, y));
                                    }
                                    None => std::thread::sleep(Duration::from_micros(200)),
                                }
                            }
                            Err((_, e)) => panic!("unexpected submit error: {e}"),
                        }
                    }
                }
                for (len, u, rx) in pending {
                    let y = rx.recv().expect("no lost replies").expect("conv ok").data;
                    done.push((len, u, y));
                }
                assert_eq!(done.len(), PER_CLIENT, "client {c} lost replies");
                replies_total.fetch_add(done.len() as u64, Ordering::Relaxed);
                // Bitwise parity vs the direct single-worker service.
                for (len, u, y) in done {
                    assert_eq!(y.len(), HEADS * len);
                    let want = single.call(forward(len, u)).expect("single-worker conv ok");
                    assert_eq!(y, want, "client {c}: fleet output diverged from single worker");
                }
            });
        }
    });

    let total = (CLIENTS * PER_CLIENT) as u64;
    assert_eq!(replies_total.load(Ordering::Relaxed), total, "zero lost replies");

    // Fleet statistics reconcile with the client-side counts.
    let stats = fleet.stats();
    assert_eq!(stats.completed, total, "every admitted request settled");
    assert_eq!(stats.requests, total, "dispatched == admitted");
    assert_eq!(stats.rows_executed, total);
    assert_eq!(stats.errors, 0);
    assert_eq!(stats.shard_deaths, 0);
    assert_eq!(stats.restarts, 0);
    assert_eq!(stats.inflight, 0, "quiescent fleet holds no slots");
    assert_eq!(stats.busy_rejections, busy_total.load(Ordering::Relaxed));
    assert_eq!(stats.submitted, total + stats.busy_rejections);
    let per_shard_sum: u64 = stats.shards.iter().map(|s| s.requests).sum();
    assert_eq!(per_shard_sum, total);
    let used = stats.shards.iter().filter(|s| s.requests > 0).count();
    assert!(used >= 2, "load balancing must spread 512 requests past one shard (used {used})");
    assert!(stats.p50_ms > 0.0 && stats.p50_ms <= stats.p99_ms);
    assert!(stats.mean_occupancy >= 1.0);
}

#[test]
fn busy_exactly_at_max_inflight_never_spurious() {
    // One request per bucket (each below the per-bucket batch capacity)
    // plus a long deadline: admitted requests deterministically stay in
    // flight until the deadline flush, so the inflight gauge is exact.
    // Buckets used: Forward 256/1024/4096 + Causal 512 — one request in
    // each of four distinct batcher queues.
    let fleet = conv_fleet(1, 4, 2, 250);
    let mut rng = Rng::new(7);
    for round in 0..3 {
        let mut pending = vec![];
        // Exactly max_inflight admissions succeed, with no spurious Busy.
        for (i, &len) in [256usize, 1024, 4096].iter().enumerate() {
            let u = rng.normal_vec(HEADS * len);
            match fleet.submit(forward(len, u)) {
                Ok(rx) => pending.push(rx),
                Err(e) => panic!("round {round}: admission {i} spuriously rejected: {e}"),
            }
        }
        {
            let u = rng.normal_vec(HEADS * 512);
            let req = ConvRequest { kind: ConvKind::Causal, len: 512, streams: vec![u], chunk_tx: None };
            match fleet.submit(req) {
                Ok(rx) => pending.push(rx),
                Err(e) => panic!("round {round}: causal admission spuriously rejected: {e}"),
            }
        }
        // The next submits are rejected exactly at the bound.
        for _ in 0..2 {
            let u = rng.normal_vec(HEADS * 256);
            match fleet.submit(forward(256, u)) {
                Err(FleetError::Busy) => {}
                other => panic!("round {round}: expected Busy at the bound, got {other:?}"),
            }
        }
        assert_eq!(fleet.stats().inflight, 4);
        for rx in pending {
            rx.recv().expect("fleet alive").expect("conv ok");
        }
        // Slots are released before replies are observable: the next
        // round's admissions must not see stale occupancy.
    }
    let stats = fleet.stats();
    assert_eq!(stats.busy_rejections, 6);
    assert_eq!(stats.completed, 12);
    assert_eq!(stats.submitted, 18);
}

#[test]
fn blocking_call_waits_out_backpressure() {
    let fleet = conv_fleet(1, 1, 4, 120);
    let mut rng = Rng::new(11);
    let u = rng.normal_vec(HEADS * 256);
    let rx = fleet.submit(forward(256, u)).expect("first request admits");
    // The bound is reached: non-blocking submit pushes back...
    let u2 = rng.normal_vec(HEADS * 256);
    assert_eq!(fleet.submit(forward(256, u2.clone())).err(), Some(FleetError::Busy));
    // ...but the blocking call waits for the slot and completes.
    std::thread::scope(|s| {
        let fleet = &fleet;
        let req = forward(256, u2);
        let caller = s.spawn(move || fleet.call(req));
        let y1 = rx.recv().expect("fleet alive").expect("conv ok").data;
        assert_eq!(y1.len(), HEADS * 256);
        let y2 =
            caller.join().expect("caller thread").expect("blocking call admits and succeeds");
        assert_eq!(y2.len(), HEADS * 256);
    });
    let stats = fleet.stats();
    assert_eq!(stats.busy_rejections, 1, "the blocking call never counts as Busy");
    assert_eq!(stats.completed, 2);
}

#[test]
fn model_server_counts_failed_handoffs_instead_of_silent_drop() {
    // Regression: ModelServer::submit used to ignore a failed hand-off to
    // a dead worker without bumping stats.errors, leaving the client with
    // a disconnected channel and no accounting. On the fleet admission
    // path the reply slot fails fast (typed, retryable) and is counted.
    let server = ModelServer::start(
        BackendConfig::Native,
        "lm_fwd_logits",
        BatchPolicy { batch_size: 4, max_wait: Duration::from_millis(400) },
    )
    .expect("server starts");
    let tokens = vec![1i32; server.seq_len];

    let rx = server.submit(InferRequest { tokens: tokens.clone() });
    server.fleet().poison_shard(0);
    let reply = rx
        .recv_timeout(Duration::from_secs(30))
        .expect("the in-flight request must receive an explicit reply, not a silent drop");
    assert_eq!(reply, Err(FleetError::ShardDied), "fail-fast must be typed and retryable");
    assert!(reply.unwrap_err().retryable());
    assert!(
        server.stats().errors.load(Ordering::Relaxed) >= 1,
        "the failed hand-off must be counted"
    );

    // The supervisor respawns the worker; subsequent requests succeed.
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        match server.fleet().call(InferRequest { tokens: tokens.clone() }) {
            Ok(logits) => {
                assert_eq!(logits.len(), server.vocab);
                break;
            }
            Err(e) if e.retryable() => {
                assert!(Instant::now() < deadline, "respawned worker never came back");
                std::thread::sleep(Duration::from_millis(50));
            }
            Err(e) => panic!("unexpected error after respawn: {e}"),
        }
    }
    let stats = server.fleet().stats();
    assert!(stats.restarts >= 1, "the supervisor must record the respawn");
    assert!(stats.shard_deaths >= 1);
}
