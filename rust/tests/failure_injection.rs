//! Failure-injection tests: the runtime must fail loudly and precisely on
//! malformed artifacts, wrong shapes, truncated fixtures/goldens, and
//! abusive service requests — never silently compute garbage.

use std::path::PathBuf;

use flashfftconv::coordinator::router::{ConvKind, Router};
use flashfftconv::runtime::{HostTensor, Runtime};
use flashfftconv::util::manifest::Manifest;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.txt").exists().then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => {
                eprintln!("skipping: run `make artifacts` first");
                return;
            }
        }
    };
}

#[test]
fn wrong_input_shape_is_an_error_not_garbage() {
    let dir = require_artifacts!();
    let runtime = Runtime::new(&dir).unwrap();
    let mut art = runtime.load("conv_fwd_monarch_n256").unwrap();
    // Wrong N.
    let err = art
        .call(&[
            HostTensor::zeros(&[2, 16, 128]),
            HostTensor::zeros(&[16, 128]),
        ])
        .unwrap_err();
    assert!(format!("{err:#}").contains("expected"), "{err:#}");
    // Wrong dtype.
    let err = art
        .call(&[
            HostTensor::i32(vec![0; 2 * 16 * 256], &[2, 16, 256]),
            HostTensor::zeros(&[16, 256]),
        ])
        .unwrap_err();
    assert!(format!("{err:#}").contains("expected"), "{err:#}");
    // Wrong arity.
    let err = art.call(&[HostTensor::zeros(&[2, 16, 256])]).unwrap_err();
    assert!(format!("{err:#}").contains("runtime inputs"), "{err:#}");
}

#[test]
fn set_operand_validates() {
    let dir = require_artifacts!();
    let runtime = Runtime::new(&dir).unwrap();
    let mut art = runtime.load("conv_fwd_monarch_n256").unwrap();
    // Unknown operand.
    assert!(art.set_operand("nope", &HostTensor::zeros(&[1])).is_err());
    // Runtime inputs cannot be pinned.
    assert!(art.set_operand("u", &HostTensor::zeros(&[2, 16, 256])).is_err());
    // Shape mismatch on a const operand.
    assert!(art.set_operand("tw_re", &HostTensor::zeros(&[1, 1])).is_err());
    // Reading a runtime input as state fails.
    assert!(art.state("u").is_err());
}

#[test]
fn truncated_fixture_detected_at_load() {
    let dir = require_artifacts!();
    // Copy one artifact's files into a temp dir with a truncated fixture.
    let tmp = std::env::temp_dir().join(format!("ffc_trunc_{}", std::process::id()));
    std::fs::create_dir_all(&tmp).unwrap();
    let manifest = Manifest::load(&dir).unwrap();
    let spec = manifest.get("conv_fwd_monarch_n256").unwrap();
    let mut text = String::from("version 1\n");
    text.push_str(&std::fs::read_to_string(dir.join("manifest.txt")).unwrap()
        [manifest_slice(&dir, "conv_fwd_monarch_n256")]);
    std::fs::write(tmp.join("manifest.txt"), &text).unwrap();
    std::fs::copy(dir.join(&spec.hlo_file), tmp.join(&spec.hlo_file)).unwrap();
    // Truncate the fixture to 8 bytes.
    std::fs::write(tmp.join("conv_fwd_monarch_n256.fix.bin"), [0u8; 8]).unwrap();
    if let Some(g) = &spec.golden_file {
        std::fs::copy(dir.join(g), tmp.join(g)).unwrap();
    }
    let runtime = Runtime::new(&tmp).unwrap();
    let err = match runtime.load("conv_fwd_monarch_n256") {
        Err(e) => e,
        Ok(_) => panic!("truncated fixture must not load"),
    };
    assert!(format!("{err:#}").contains("too short"), "{err:#}");
    let _ = std::fs::remove_dir_all(&tmp);
}

/// Extract one artifact's manifest block (helper for the truncation test).
fn manifest_slice(dir: &std::path::Path, name: &str) -> std::ops::Range<usize> {
    let text = std::fs::read_to_string(dir.join("manifest.txt")).unwrap();
    let start = text.find(&format!("artifact {name}\n")).unwrap();
    let end = text[start..].find("\nend\n").unwrap() + start + "\nend\n".len();
    start..end
}

#[test]
fn truncated_golden_detected() {
    let dir = require_artifacts!();
    let tmp = std::env::temp_dir().join(format!("ffc_gold_{}", std::process::id()));
    std::fs::create_dir_all(&tmp).unwrap();
    let manifest = Manifest::load(&dir).unwrap();
    let spec = manifest.get("conv_fwd_monarch_n256").unwrap().clone();
    let mut text = String::from("version 1\n");
    text.push_str(
        &std::fs::read_to_string(dir.join("manifest.txt")).unwrap()
            [manifest_slice(&dir, "conv_fwd_monarch_n256")],
    );
    std::fs::write(tmp.join("manifest.txt"), &text).unwrap();
    std::fs::copy(dir.join(&spec.hlo_file), tmp.join(&spec.hlo_file)).unwrap();
    std::fs::copy(
        dir.join("conv_fwd_monarch_n256.fix.bin"),
        tmp.join("conv_fwd_monarch_n256.fix.bin"),
    )
    .unwrap();
    std::fs::write(tmp.join(spec.golden_file.as_ref().unwrap()), [0u8; 16]).unwrap();
    let m2 = Manifest::load(&tmp).unwrap();
    let spec2 = m2.get("conv_fwd_monarch_n256").unwrap();
    let err = flashfftconv::runtime::golden::load(&m2, spec2).unwrap_err();
    assert!(format!("{err:#}").contains("truncated"), "{err:#}");
    let _ = std::fs::remove_dir_all(&tmp);
}

#[test]
fn router_rejects_oversize_and_service_reports_bad_streams() {
    let dir = require_artifacts!();
    let runtime = Runtime::new(&dir).unwrap();
    let router = Router::from_manifest(runtime.manifest(), "monarch").unwrap();
    assert!(router.route(ConvKind::Forward, 1 << 24).is_err());

    use flashfftconv::coordinator::service::{ConvRequest, ConvService};
    use flashfftconv::coordinator::BatchPolicy;
    let service = ConvService::start(
        &dir,
        "monarch",
        BatchPolicy { batch_size: 2, max_wait: std::time::Duration::from_millis(1) },
    )
    .unwrap();
    // Wrong stream count for a gated request.
    let reply = service
        .submit(ConvRequest { kind: ConvKind::Gated, len: 256, streams: vec![vec![0.0; 16 * 256]] })
        .recv()
        .unwrap();
    assert!(reply.is_err());
    // Wrong stream size.
    let reply = service
        .submit(ConvRequest { kind: ConvKind::Forward, len: 256, streams: vec![vec![0.0; 7]] })
        .recv()
        .unwrap();
    assert!(reply.is_err());
    // Oversize request routes to an error, not a crash.
    let reply = service
        .submit(ConvRequest { kind: ConvKind::Forward, len: 1 << 24, streams: vec![vec![]] })
        .recv()
        .unwrap();
    assert!(reply.is_err());
    assert!(service.stats().errors.load(std::sync::atomic::Ordering::Relaxed) >= 3);
}

#[test]
fn trainer_rejects_non_train_artifacts() {
    let dir = require_artifacts!();
    let runtime = Runtime::new(&dir).unwrap();
    let err = flashfftconv::trainer::Trainer::new(
        &runtime,
        flashfftconv::trainer::TrainConfig {
            artifact: "conv_fwd_monarch_n256".into(),
            budget: flashfftconv::trainer::run::Budget::Steps(1),
            log_every: 1,
            seed: 0,
            checkpoint: None,
        },
    );
    let err = match err {
        Err(e) => e,
        Ok(_) => panic!("conv artifact must not act as a trainer"),
    };
    assert!(format!("{err:#}").contains("not a train_step"), "{err:#}");
}

#[test]
fn unknown_artifact_name_is_clean_error() {
    let dir = require_artifacts!();
    let runtime = Runtime::new(&dir).unwrap();
    let err = match runtime.load("does_not_exist") {
        Err(e) => e,
        Ok(_) => panic!("unknown artifact must not load"),
    };
    assert!(format!("{err:#}").contains("not in manifest"), "{err:#}");
}
