//! Failure-injection tests: the runtime must fail loudly and precisely on
//! malformed manifests, wrong shapes, truncated fixtures/goldens, and
//! abusive service requests — never silently compute garbage. All tests
//! run against the native backend (no artifacts needed, no skips).

use std::collections::BTreeMap;

use flashfftconv::coordinator::router::{ConvKind, Router};
use flashfftconv::runtime::native::default_fleet_parts;
use flashfftconv::runtime::{BackendConfig, HostTensor, Runtime};

fn native() -> Runtime {
    Runtime::native().expect("native backend constructs")
}

#[test]
fn wrong_input_shape_is_an_error_not_garbage() {
    let runtime = native();
    let mut art = runtime.load("conv_fwd_monarch_n256").unwrap();
    // Wrong N.
    let err = art
        .call(&[
            HostTensor::zeros(&[2, 16, 128]),
            HostTensor::zeros(&[16, 128]),
        ])
        .unwrap_err();
    assert!(format!("{err:#}").contains("expected"), "{err:#}");
    // Wrong dtype.
    let err = art
        .call(&[
            HostTensor::i32(vec![0; 2 * 16 * 256], &[2, 16, 256]),
            HostTensor::zeros(&[16, 256]),
        ])
        .unwrap_err();
    assert!(format!("{err:#}").contains("expected"), "{err:#}");
    // Wrong arity.
    let err = art.call(&[HostTensor::zeros(&[2, 16, 256])]).unwrap_err();
    assert!(format!("{err:#}").contains("runtime inputs"), "{err:#}");
}

#[test]
fn set_operand_validates() {
    let runtime = native();
    let mut art = runtime.load("conv_fwd_monarch_n256").unwrap();
    // Unknown operand.
    assert!(art.set_operand("nope", &HostTensor::zeros(&[1])).is_err());
    // Runtime inputs cannot be pinned.
    assert!(art.set_operand("u", &HostTensor::zeros(&[2, 16, 256])).is_err());
    // Shape mismatch on a const operand.
    assert!(art.set_operand("tw_re", &HostTensor::zeros(&[1, 1])).is_err());
    // Reading a runtime input as state fails.
    assert!(art.state("u").is_err());
}

#[test]
fn swapped_twiddle_operand_fails_at_execute() {
    let runtime = native();
    let mut art = runtime.load("conv_fwd_monarch_n256").unwrap();
    // Correct shape but wrong values: accepted by set_operand (it only
    // checks the signature), then rejected loudly at the next execute —
    // the native engine verifies const operands instead of silently
    // ignoring them.
    art.set_operand("tw_re", &HostTensor::zeros(&[16, 16])).unwrap();
    let err = art
        .call(&[HostTensor::zeros(&[2, 16, 256]), HostTensor::zeros(&[16, 256])])
        .unwrap_err();
    assert!(format!("{err:#}").contains("twiddle"), "{err:#}");
}

#[test]
fn inconsistent_manifest_dims_rejected_at_load() {
    // A parsable manifest whose meta dims disagree with its declared
    // tensor shapes must fail at load, not panic at execute.
    let text = "version 1\nartifact bad_conv\nhlo bad_conv.hlo.txt\nmeta group conv\n\
                meta kind conv_fwd\nmeta variant monarch\nmeta seq_len 512\n\
                meta batch 2\nmeta heads 16\n\
                input u f32 2,16,256 runtime\ninput k f32 16,256 runtime\n\
                output y f32 2,16,256\nend\n";
    let runtime = Runtime::native_from(text, BTreeMap::new()).unwrap();
    let err = runtime.load("bad_conv").unwrap_err();
    assert!(format!("{err:#}").contains("engine needs"), "{err:#}");

    // A gated artifact missing its gate inputs is equally rejected.
    let text = "version 1\nartifact bad_gated\nhlo bad_gated.hlo.txt\nmeta group conv\n\
                meta kind conv_gated\nmeta variant monarch\nmeta seq_len 256\n\
                meta batch 2\nmeta heads 16\n\
                input u f32 2,16,256 runtime\ninput k f32 16,256 runtime\n\
                output y f32 2,16,256\nend\n";
    let runtime = Runtime::native_from(text, BTreeMap::new()).unwrap();
    let err = runtime.load("bad_gated").unwrap_err();
    assert!(format!("{err:#}").contains("declares no input"), "{err:#}");
}

#[test]
fn truncated_fixture_detected_at_load() {
    // Take the generated fleet and truncate one artifact's fixture blob.
    let (text, mut files) = default_fleet_parts();
    let fix = files.get_mut("conv_fwd_monarch_n256.fix").expect("fixture exists");
    fix.truncate(8);
    let runtime = Runtime::native_from(&text, files).unwrap();
    let err = match runtime.load("conv_fwd_monarch_n256") {
        Err(e) => e,
        Ok(_) => panic!("truncated fixture must not load"),
    };
    assert!(format!("{err:#}").contains("too short"), "{err:#}");
    // Other artifacts with intact fixtures still load.
    runtime.load("conv_fwd_baseline_n256").unwrap();
}

#[test]
fn truncated_golden_detected() {
    let (text, mut files) = default_fleet_parts();
    let g = files.get_mut("conv_fwd_monarch_n256.golden").expect("golden exists");
    g.truncate(16);
    let runtime = Runtime::native_from(&text, files).unwrap();
    let spec = runtime.manifest().get("conv_fwd_monarch_n256").unwrap().clone();
    let err = flashfftconv::runtime::golden::load(&runtime, &spec).unwrap_err();
    assert!(format!("{err:#}").contains("truncated"), "{err:#}");
}

#[test]
fn oversized_golden_detected() {
    let (text, mut files) = default_fleet_parts();
    let g = files.get_mut("conv_fwd_monarch_n256.golden").expect("golden exists");
    g.extend_from_slice(&[0u8; 5]);
    let runtime = Runtime::native_from(&text, files).unwrap();
    let spec = runtime.manifest().get("conv_fwd_monarch_n256").unwrap().clone();
    let err = flashfftconv::runtime::golden::load(&runtime, &spec).unwrap_err();
    assert!(format!("{err:#}").contains("trailing"), "{err:#}");
}

#[test]
fn missing_fixture_file_is_clean_error() {
    let (text, mut files) = default_fleet_parts();
    files.remove("conv_gated_monarch_n256.fix");
    let runtime = Runtime::native_from(&text, files).unwrap();
    let err = runtime.load("conv_gated_monarch_n256").unwrap_err();
    assert!(format!("{err:#}").contains("not present"), "{err:#}");
}

#[test]
fn malformed_manifest_rejected() {
    let bad = "version 1\nartifact a\nhlo a.hlo.txt\n"; // no `end`
    assert!(Runtime::native_from(bad, BTreeMap::new()).is_err());
    let bad = "version 7\n";
    assert!(Runtime::native_from(bad, BTreeMap::new()).is_err());
}

#[test]
fn artifact_without_native_engine_rejected_at_load() {
    let text = "version 1\nartifact mystery\nhlo mystery.hlo.txt\nmeta kind warp_drive\n\
                input x f32 4 runtime\noutput y f32 4\nend\n";
    let runtime = Runtime::native_from(text, BTreeMap::new()).unwrap();
    let err = runtime.load("mystery").unwrap_err();
    assert!(format!("{err:#}").contains("no native engine"), "{err:#}");
}

#[test]
fn router_rejects_oversize_and_service_reports_bad_streams() {
    let runtime = native();
    let router = Router::from_manifest(runtime.manifest(), "monarch").unwrap();
    assert!(router.route(ConvKind::Forward, 1 << 24).is_err());

    use flashfftconv::coordinator::service::{ConvRequest, ConvService};
    use flashfftconv::coordinator::BatchPolicy;
    let service = ConvService::start(
        BackendConfig::Native,
        "monarch",
        BatchPolicy { batch_size: 2, max_wait: std::time::Duration::from_millis(1) },
    )
    .unwrap();
    // Wrong stream count for a gated request.
    let reply = service
        .submit(ConvRequest { kind: ConvKind::Gated, len: 256, streams: vec![vec![0.0; 16 * 256]], chunk_tx: None })
        .recv()
        .unwrap();
    assert!(reply.is_err());
    // Wrong stream size.
    let reply = service
        .submit(ConvRequest { kind: ConvKind::Forward, len: 256, streams: vec![vec![0.0; 7]], chunk_tx: None })
        .recv()
        .unwrap();
    assert!(reply.is_err());
    // Oversize request routes to an error, not a crash.
    let reply = service
        .submit(ConvRequest { kind: ConvKind::Forward, len: 1 << 24, streams: vec![vec![]], chunk_tx: None })
        .recv()
        .unwrap();
    assert!(reply.is_err());
    assert!(service.stats().errors.load(std::sync::atomic::Ordering::Relaxed) >= 3);
}

#[test]
fn trainer_rejects_non_train_artifacts() {
    let runtime = native();
    let err = flashfftconv::trainer::Trainer::new(
        &runtime,
        flashfftconv::trainer::TrainConfig {
            artifact: "conv_fwd_monarch_n256".into(),
            budget: flashfftconv::trainer::run::Budget::Steps(1),
            log_every: 1,
            seed: 0,
            checkpoint: None,
        },
    );
    let err = match err {
        Err(e) => e,
        Ok(_) => panic!("conv artifact must not act as a trainer"),
    };
    assert!(format!("{err:#}").contains("not a train_step"), "{err:#}");
}

#[test]
fn unknown_artifact_name_is_clean_error() {
    let runtime = native();
    let err = match runtime.load("does_not_exist") {
        Err(e) => e,
        Ok(_) => panic!("unknown artifact must not load"),
    };
    assert!(format!("{err:#}").contains("not in manifest"), "{err:#}");
}

#[test]
fn out_of_vocab_tokens_are_an_error() {
    let runtime = native();
    let mut art = runtime.load("lm_tiny_train").unwrap();
    let spec = art.spec().clone();
    let batch = spec.meta_usize("batch").unwrap();
    let seq = spec.meta_usize("seq_len").unwrap();
    let tokens = vec![9999i32; batch * (seq + 1)];
    let err = art.step(&[HostTensor::i32(tokens, &[batch, seq + 1])]).unwrap_err();
    assert!(format!("{err:#}").contains("out of range"), "{err:#}");
}

#[test]
fn shard_death_respawns_and_fails_fast() {
    // Kill a shard worker mid-stream (poison hook) while requests sit in
    // its batcher: the dispatcher must fail that shard's in-flight work
    // fast with a retryable error (no hung clients), the surviving shard
    // must complete its requests, the supervisor must respawn the dead
    // worker (restart counter), and subsequent requests must succeed.
    use flashfftconv::coordinator::fleet::{FleetConfig, FleetDispatcher, FleetError};
    use flashfftconv::coordinator::service::ConvRequest;
    use flashfftconv::coordinator::BatchPolicy;
    use flashfftconv::util::Rng;
    use std::time::{Duration, Instant};

    const HEADS: usize = 16;
    let fleet = FleetDispatcher::conv(
        BackendConfig::NativeRowThreads(1),
        "monarch",
        FleetConfig {
            shards: 2,
            max_inflight: 1024,
            // Batch capacity is clamped to the artifact's batch dim (2),
            // so keep one request per bucket per shard in flight: the
            // four Forward buckets 256/1024/4096 + Causal 512 spread one
            // job into each shard's queues under least-outstanding
            // balancing, none flushing before the long deadline.
            policy: BatchPolicy { batch_size: 2, max_wait: Duration::from_millis(800) },
        },
    )
    .expect("fleet starts");

    let mut rng = Rng::new(77);
    let mut pending = vec![];
    for &len in &[256usize, 1024, 4096] {
        for _ in 0..2 {
            let u = rng.normal_vec(HEADS * len);
            let req =
                ConvRequest { kind: flashfftconv::coordinator::router::ConvKind::Forward, len, streams: vec![u], chunk_tx: None };
            pending.push(fleet.submit(req).expect("admitted"));
        }
    }
    fleet.poison_shard(0);

    let (mut ok, mut died) = (0usize, 0usize);
    for rx in pending {
        match rx
            .recv_timeout(Duration::from_secs(60))
            .expect("no hung clients: every in-flight request must get a reply")
        {
            Ok(reply) => {
                assert!(!reply.data.is_empty() && reply.data.iter().all(|v| v.is_finite()));
                ok += 1;
            }
            Err(FleetError::ShardDied) => died += 1,
            Err(e) => panic!("unexpected reply error: {e}"),
        }
    }
    assert!(died >= 1, "the poisoned shard must fail its in-flight requests fast");
    assert!(ok >= 1, "the surviving shard must complete its requests (ok={ok} died={died})");

    // The supervisor records the respawn.
    let deadline = Instant::now() + Duration::from_secs(30);
    while fleet.stats().restarts == 0 {
        assert!(Instant::now() < deadline, "supervisor never respawned the shard");
        std::thread::sleep(Duration::from_millis(20));
    }
    let stats = fleet.stats();
    assert!(stats.restarts >= 1);
    assert!(stats.shard_deaths >= died as u64);
    assert_eq!(stats.inflight, 0, "failed-fast slots must be released");

    // Subsequent requests succeed once the respawned worker is back (a
    // submit can race the dead window, so retry on retryable errors).
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let u = rng.normal_vec(HEADS * 256);
        let req = ConvRequest {
            kind: flashfftconv::coordinator::router::ConvKind::Forward,
            len: 256,
            streams: vec![u], chunk_tx: None
        };
        match fleet.call(req) {
            Ok(row) => {
                assert_eq!(row.len(), HEADS * 256);
                break;
            }
            Err(e) if e.retryable() => {
                assert!(Instant::now() < deadline, "fleet never recovered after the respawn");
                std::thread::sleep(Duration::from_millis(20));
            }
            Err(e) => panic!("unexpected error after respawn: {e}"),
        }
    }
}

#[test]
fn control_ops_survive_poisoned_shard_and_converge_on_one_epoch() {
    // Kill a shard and land a control op in the same breath: the op is
    // logged under the senders lock, so the supervisor's respawn replays
    // it onto the fresh worker and the whole fleet converges on one
    // epoch — no shard may keep serving the pre-swap filter, and no
    // reply may carry a pre-swap epoch after the flip.
    use flashfftconv::coordinator::fleet::{FleetConfig, FleetDispatcher};
    use flashfftconv::coordinator::service::{ConvControl, ConvRequest, ConvService};
    use flashfftconv::coordinator::BatchPolicy;
    use flashfftconv::util::Rng;
    use std::time::{Duration, Instant};

    const HEADS: usize = 16;
    let policy = BatchPolicy { batch_size: 2, max_wait: Duration::from_millis(1) };
    let fleet = FleetDispatcher::conv(
        BackendConfig::NativeRowThreads(1),
        "monarch",
        FleetConfig { shards: 2, max_inflight: 1024, policy: policy.clone() },
    )
    .expect("fleet starts");
    let single =
        ConvService::start(BackendConfig::Native, "monarch", policy).expect("reference starts");

    let kind = ConvKind::Forward;
    let mut rng = Rng::new(0xE04);
    let k1 = rng.normal_vec(HEADS * 256);
    let e1 = fleet
        .control(ConvControl::SetFilter { kind, bucket: 256, k: k1 })
        .expect("first install");
    assert_eq!(e1, 1);

    // Poison shard 0, then immediately broadcast the second install: the
    // dying shard's ack channel tears mid-broadcast, yet the op must
    // still become visible fleet-wide.
    fleet.poison_shard(0);
    let k2 = rng.normal_vec(HEADS * 256);
    let e2 = fleet
        .control(ConvControl::SetFilter { kind, bucket: 256, k: k2.clone() })
        .expect("control must apply across a mid-broadcast shard death");
    assert_eq!(e2, 2);
    assert_eq!(fleet.filter_epoch(), 2);
    single.set_filter(kind, 256, k2).expect("reference install");

    // Wait for the supervisor to respawn the poisoned worker (the
    // respawn replays the control log before the shard goes live).
    let deadline = Instant::now() + Duration::from_secs(30);
    while fleet.stats().restarts == 0 {
        assert!(Instant::now() < deadline, "supervisor never respawned the shard");
        std::thread::sleep(Duration::from_millis(20));
    }

    // Concurrent bursts so both shards serve: every reply must carry the
    // post-swap epoch and the k2 outputs — a respawned worker stuck on
    // the pre-swap filter (or a reply tagged with a stale epoch) fails
    // here.
    let deadline = Instant::now() + Duration::from_secs(60);
    let mut done = 0usize;
    while done < 12 {
        assert!(Instant::now() < deadline, "fleet never recovered after the respawn");
        let mut pending = vec![];
        for _ in 0..6 {
            let u = rng.normal_vec(HEADS * 256);
            let req = ConvRequest { kind, len: 256, streams: vec![u.clone()], chunk_tx: None };
            match fleet.submit_blocking(req) {
                Ok(rx) => pending.push((u, rx)),
                Err(e) if e.retryable() => std::thread::sleep(Duration::from_millis(10)),
                Err(e) => panic!("unexpected submit error: {e}"),
            }
        }
        for (u, rx) in pending {
            match rx.recv().expect("every admitted request gets a reply") {
                Ok(ok) => {
                    assert_eq!(ok.epoch, e2, "reply carried a pre-swap epoch");
                    let want = single
                        .call(ConvRequest { kind, len: 256, streams: vec![u], chunk_tx: None })
                        .expect("reference conv");
                    assert_eq!(ok.data, want, "a shard served the pre-swap filter");
                    done += 1;
                }
                Err(e) if e.retryable() => std::thread::sleep(Duration::from_millis(10)),
                Err(e) => panic!("unexpected reply error: {e}"),
            }
        }
    }
}

#[test]
fn poisoned_plan_registry_recovers_and_serves() {
    // Headline PR-9 regression: a panic on any thread holding a plan
    // registry lock used to poison the process-wide Mutex, turning every
    // subsequent plan lookup — and therefore every later engine build in
    // the process — into a cascading panic far from the original fault.
    // The registries are insert-only maps of finished plans (a panicking
    // holder cannot leave a torn entry), so the locks now shrug off
    // poisoning and a wounded process keeps serving.
    use flashfftconv::coordinator::fleet::{FleetConfig, FleetDispatcher};
    use flashfftconv::coordinator::service::ConvRequest;
    use flashfftconv::coordinator::BatchPolicy;
    use flashfftconv::fft::plan;
    use flashfftconv::util::Rng;
    use std::time::Duration;

    // Deliberately panic worker threads while they hold each registry
    // lock (the failure-injection hook marks every registry poisoned).
    plan::poison_registries();

    // Plan lookups recover instead of propagating the old panic —
    // both cache hits (the fleets below re-request these shapes) and
    // fresh builds.
    plan::plan(256, 2).expect("complex plan lookup after poisoning");
    plan::real_plan(512, 2).expect("real plan lookup after poisoning");
    plan::real_plan_f32(512, 2).expect("f32 plan lookup after poisoning");

    // And the full request path — backend build, engine construction,
    // plan registry traffic, dispatch, execute — still works end to end.
    const HEADS: usize = 16;
    let fleet = FleetDispatcher::conv(
        BackendConfig::NativeRowThreads(1),
        "monarch",
        FleetConfig {
            shards: 1,
            max_inflight: 64,
            policy: BatchPolicy { batch_size: 2, max_wait: Duration::from_millis(1) },
        },
    )
    .expect("fleet starts on poisoned registries");
    let mut rng = Rng::new(0x9015);
    let u = rng.normal_vec(HEADS * 256);
    let row = fleet
        .call(ConvRequest { kind: ConvKind::Forward, len: 256, streams: vec![u], chunk_tx: None })
        .expect("conv request served after registry poisoning");
    assert_eq!(row.len(), HEADS * 256);
}
