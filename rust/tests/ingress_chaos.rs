//! Fault-injection suite for the hardened ingress (PR 8 tentpole):
//! every adversarial shape — slow-loris dribbles, mid-frame disconnects,
//! stalled readers, quota abuse, shard poison mid-stream — must surface
//! as a *typed* retryable/non-retryable wire status, never a hang, a
//! panic, or a silently dropped reply. Faults are injected with the
//! reusable [`flashfftconv::ingress::fault`] layer (direct
//! `FaultyStream` wrapping and the `ChaosProxy` TCP man-in-the-middle).
//!
//! The acceptance soak at the bottom drives a 4-shard fleet with 8
//! well-behaved wire clients (bitwise parity against an in-process
//! `ConvService`, zero lost or duplicated replies, per-connection epoch
//! monotonicity) while chaos clients dribble and cut and a shard is
//! poisoned mid-soak; a ≥1M-point conv reply round-trips bit-exactly
//! through the wire-v2 streamed chunk path.

use std::io::Write;
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use flashfftconv::coordinator::router::ConvKind;
use flashfftconv::coordinator::service::{ConvRequest, ConvService};
use flashfftconv::coordinator::BatchPolicy;
use flashfftconv::ingress::client::IngressClient;
use flashfftconv::ingress::fault::{ChaosProxy, FaultPlan};
use flashfftconv::ingress::limits::RateLimit;
use flashfftconv::ingress::wire::{self, Reply, Request};
use flashfftconv::ingress::{IngressConfig, IngressServer};
use flashfftconv::runtime::BackendConfig;
use flashfftconv::util::Rng;

const HEADS: usize = 16;

fn single() -> Arc<ConvService> {
    Arc::new(
        ConvService::start(
            BackendConfig::Native,
            "monarch",
            BatchPolicy { batch_size: 2, max_wait: Duration::from_millis(2) },
        )
        .expect("service starts"),
    )
}

fn sharded(shards: usize, max_inflight: usize) -> Arc<ConvService> {
    Arc::new(
        ConvService::start_sharded(
            BackendConfig::NativeRowThreads(1),
            "monarch",
            BatchPolicy { batch_size: 2, max_wait: Duration::from_millis(2) },
            shards,
            max_inflight,
        )
        .expect("sharded service starts"),
    )
}

fn bind(service: &Arc<ConvService>, cfg: IngressConfig) -> IngressServer {
    IngressServer::bind("127.0.0.1:0", Some(Arc::clone(service)), None, cfg)
        .expect("ingress binds")
}

fn conv_req(len: usize, u: Vec<f32>) -> Request {
    Request::Conv { kind: 0, len: len as u32, streams: vec![u] }
}

/// Poll `cond` until it holds or `secs` elapse.
fn eventually(secs: u64, mut cond: impl FnMut() -> bool) -> bool {
    let deadline = Instant::now() + Duration::from_secs(secs);
    loop {
        if cond() {
            return true;
        }
        if Instant::now() >= deadline {
            return false;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
}

// ---------------------------------------------------------------------------
// Read deadlines: slow-loris and dribblers
// ---------------------------------------------------------------------------

#[test]
fn slow_loris_is_evicted_while_other_connections_progress() {
    let service = single();
    let ingress = bind(
        &service,
        IngressConfig {
            idle_timeout: Some(Duration::from_secs(10)),
            frame_timeout: Some(Duration::from_millis(300)),
            ..IngressConfig::default()
        },
    );
    let addr = ingress.local_addr();

    // The loris: one clean round trip (so the server knows it speaks
    // v2 and will answer with a typed timed_out), then two bytes of a
    // new frame and silence, pinning a pool slot — until the frame
    // deadline evicts it.
    let mut loris = TcpStream::connect(addr).expect("loris connects");
    loris.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut rng = Rng::new(0xC4A0);
    let u = rng.normal_vec(HEADS * 256);
    loris.write_all(&wire::encode_request(1, &conv_req(256, u))).expect("clean frame");
    let body = wire::read_frame(&mut loris).expect("read ok").expect("reply present");
    assert!(matches!(
        wire::decode_reply(&body).expect("decodes"),
        (1, Reply::Ok { .. }) | (1, Reply::Busy)
    ));
    let t0 = Instant::now();
    loris.write_all(&[0xAB, 0xCD]).expect("dribble two bytes");

    // While the loris stalls, a well-behaved connection keeps serving.
    let mut good = IngressClient::connect(addr).expect("good client connects");
    for _ in 0..4 {
        let u = rng.normal_vec(HEADS * 256);
        match good
            .call_retry(&conv_req(256, u), 64, Duration::from_millis(1))
            .expect("good client round trip")
        {
            Reply::Ok { data, .. } => assert_eq!(data.len(), HEADS * 256),
            other => panic!("good client starved by the loris: {other:?}"),
        }
    }
    good.finish();

    // The loris gets a typed timed_out notice, then EOF — well before
    // the 10 s idle timeout (the *frame* deadline is what fires: partial
    // bytes must not count as keep-alive).
    let body = wire::read_frame(&mut loris).expect("read ok").expect("notice present");
    match wire::decode_reply(&body).expect("notice decodes") {
        (0, Reply::TimedOut { msg }) => {
            assert!(msg.contains("deadline"), "notice must name the deadline: {msg}")
        }
        other => panic!("expected timed_out eviction notice, got {other:?}"),
    }
    assert!(
        wire::read_frame(&mut loris).expect("post-notice read").is_none(),
        "the connection must be closed after the eviction notice"
    );
    assert!(
        t0.elapsed() < Duration::from_secs(8),
        "eviction must beat the idle timeout (frame deadline governs): {:?}",
        t0.elapsed()
    );
    assert!(ingress.stats().read_timeouts.load(Ordering::Relaxed) >= 1);
    assert!(eventually(10, || ingress.open_connections() == 0));
}

#[test]
fn dribbled_request_completes_under_a_generous_frame_deadline() {
    let service = single();
    let ingress = bind(
        &service,
        IngressConfig {
            idle_timeout: Some(Duration::from_secs(10)),
            frame_timeout: Some(Duration::from_secs(8)),
            ..IngressConfig::default()
        },
    );
    // 512-byte chunks with 1 ms pauses: a ~16 KiB conv frame arrives in
    // ~35 dribbles, well inside the deadline — throttled-but-honest
    // clients are served, not evicted.
    let proxy = ChaosProxy::start(
        ingress.local_addr(),
        FaultPlan { chunk: 512, delay: Duration::from_millis(1), ..FaultPlan::default() },
        FaultPlan::clean(),
    )
    .expect("proxy starts");

    let mut rng = Rng::new(0xD81B);
    let mut client = IngressClient::connect(proxy.local_addr()).expect("client connects");
    client.set_timeouts(Some(Duration::from_secs(30)), None).expect("timeouts set");
    let u = rng.normal_vec(HEADS * 256);
    match client
        .call_retry(&conv_req(256, u), 64, Duration::from_millis(1))
        .expect("dribbled round trip")
    {
        Reply::Ok { data, .. } => assert_eq!(data.len(), HEADS * 256),
        other => panic!("dribbled-but-timely request must serve: {other:?}"),
    }
    client.finish();
    assert_eq!(ingress.stats().read_timeouts.load(Ordering::Relaxed), 0);
}

#[test]
fn stall_past_the_frame_deadline_is_evicted_with_timed_out() {
    let service = single();
    let ingress = bind(
        &service,
        IngressConfig {
            idle_timeout: Some(Duration::from_secs(10)),
            frame_timeout: Some(Duration::from_millis(300)),
            ..IngressConfig::default()
        },
    );
    // Forward the first request intact, then stall 20 bytes into the
    // second frame (held open, not closed): the absolute frame deadline
    // must fire even though the connection looks alive.
    let mut rng = Rng::new(0x57A1);
    let u1 = rng.normal_vec(HEADS * 256);
    let first = wire::encode_request(1, &conv_req(256, u1));
    let proxy = ChaosProxy::start(
        ingress.local_addr(),
        FaultPlan::stall_after(first.len() + 20),
        FaultPlan::clean(),
    )
    .expect("proxy starts");

    let mut stream = TcpStream::connect(proxy.local_addr()).expect("connect via proxy");
    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    stream.write_all(&first).expect("first frame");
    let u2 = rng.normal_vec(HEADS * 256);
    stream.write_all(&wire::encode_request(2, &conv_req(256, u2))).expect("second frame");

    // First request serves; the second stalls mid-frame and earns the
    // typed eviction.
    let body = wire::read_frame(&mut stream).expect("read ok").expect("reply present");
    assert!(matches!(
        wire::decode_reply(&body).expect("decodes"),
        (1, Reply::Ok { .. }) | (1, Reply::Busy)
    ));
    let body = wire::read_frame(&mut stream).expect("read ok").expect("notice present");
    match wire::decode_reply(&body).expect("notice decodes") {
        (0, Reply::TimedOut { .. }) => {}
        other => panic!("expected timed_out for the stalled frame, got {other:?}"),
    }
    assert!(wire::read_frame(&mut stream).expect("post-notice read").is_none());
    assert!(ingress.stats().read_timeouts.load(Ordering::Relaxed) >= 1);
}

#[test]
fn mid_frame_disconnect_tears_down_cleanly() {
    let service = single();
    let ingress = bind(&service, IngressConfig::default());
    let mut rng = Rng::new(0xCC17);
    let frame = wire::encode_request(1, &conv_req(256, rng.normal_vec(HEADS * 256)));
    // Cut the connection 10 bytes into the frame body.
    let proxy =
        ChaosProxy::start(ingress.local_addr(), FaultPlan::cut_after(14), FaultPlan::clean())
            .expect("proxy starts");

    let mut stream = TcpStream::connect(proxy.local_addr()).expect("connect via proxy");
    stream.set_read_timeout(Some(Duration::from_secs(15))).unwrap();
    let _ = stream.write_all(&frame); // the cut may surface as a write error
    // Whatever the client sees (reset or EOF), it must see it promptly —
    // and the server side must fully tear down without a reply leak.
    let t0 = Instant::now();
    let _ = wire::read_frame(&mut stream);
    assert!(t0.elapsed() < Duration::from_secs(15), "client must not hang on a cut");
    assert!(
        eventually(15, || ingress.open_connections() == 0),
        "server must reap the torn connection"
    );
    // The front still serves.
    drop(proxy);
    let mut client = IngressClient::connect(ingress.local_addr()).expect("fresh client");
    let u = rng.normal_vec(HEADS * 256);
    match client.call_retry(&conv_req(256, u), 64, Duration::from_millis(1)).expect("round trip")
    {
        Reply::Ok { data, .. } => assert_eq!(data.len(), HEADS * 256),
        other => panic!("front wedged after mid-frame cut: {other:?}"),
    }
    client.finish();
}

// ---------------------------------------------------------------------------
// Per-connection quotas
// ---------------------------------------------------------------------------

#[test]
fn rate_limit_sheds_with_busy_then_refills() {
    let service = single();
    let ingress = bind(
        &service,
        IngressConfig {
            rate_limit: Some(RateLimit::new(20.0, 2.0)),
            ..IngressConfig::default()
        },
    );
    let mut rng = Rng::new(0x8A7E);
    let mut client = IngressClient::connect(ingress.local_addr()).expect("client connects");

    // Burst 6 pipelined requests: the bucket (burst 2) admits the first
    // two and sheds the rest with retryable busy.
    let mut ids = Vec::new();
    for _ in 0..6 {
        let u = rng.normal_vec(HEADS * 256);
        ids.push(client.send(&conv_req(256, u)).expect("send"));
    }
    let (mut ok, mut busy) = (0, 0);
    for id in ids {
        let (rid, reply) = client.recv().expect("reply arrives");
        assert_eq!(rid, id, "rate shed must preserve FIFO reply order");
        match reply {
            Reply::Ok { data, .. } => {
                assert_eq!(data.len(), HEADS * 256);
                ok += 1;
            }
            Reply::Busy => busy += 1,
            other => panic!("unexpected reply under rate shed: {other:?}"),
        }
    }
    assert!(ok >= 2, "the burst allowance must serve (got {ok} ok)");
    assert!(busy >= 3, "past-burst requests must shed (got {busy} busy)");
    assert!(ingress.stats().rate_shed.load(Ordering::Relaxed) >= 3);

    // After a refill interval the same connection serves again.
    std::thread::sleep(Duration::from_millis(300));
    let u = rng.normal_vec(HEADS * 256);
    match client.call(&conv_req(256, u)).expect("post-refill round trip") {
        Reply::Ok { data, .. } => assert_eq!(data.len(), HEADS * 256),
        other => panic!("bucket must refill: {other:?}"),
    }
    client.finish();
}

#[test]
fn per_connection_inflight_cap_sheds_with_busy() {
    // Slow flush (big batch, long window) keeps admitted requests in
    // flight while the reader races ahead, so the per-connection cap is
    // what decides.
    let service = Arc::new(
        ConvService::start(
            BackendConfig::Native,
            "monarch",
            BatchPolicy { batch_size: 8, max_wait: Duration::from_millis(400) },
        )
        .expect("service starts"),
    );
    let ingress = bind(
        &service,
        IngressConfig { max_inflight_per_conn: 2, ..IngressConfig::default() },
    );
    let mut rng = Rng::new(0x1F17);
    let mut client = IngressClient::connect(ingress.local_addr()).expect("client connects");
    let mut ids = Vec::new();
    for _ in 0..6 {
        let u = rng.normal_vec(HEADS * 256);
        ids.push(client.send(&conv_req(256, u)).expect("send"));
    }
    let (mut ok, mut busy) = (0, 0);
    for id in ids {
        let (rid, reply) = client.recv().expect("reply arrives");
        assert_eq!(rid, id, "inflight shed must preserve FIFO reply order");
        match reply {
            Reply::Ok { .. } => ok += 1,
            Reply::Busy => busy += 1,
            other => panic!("unexpected reply under inflight shed: {other:?}"),
        }
    }
    assert_eq!((ok, busy), (2, 4), "cap 2 must admit 2 and shed 4");
    assert!(ingress.stats().inflight_shed.load(Ordering::Relaxed) >= 4);
    client.finish();
}

#[test]
fn byte_budget_exhaustion_gets_quota_and_a_close() {
    let service = single();
    let ingress = bind(
        &service,
        IngressConfig { conn_byte_budget: Some(20_000), ..IngressConfig::default() },
    );
    let mut rng = Rng::new(0xB06D);
    let mut client = IngressClient::connect(ingress.local_addr()).expect("client connects");
    client.set_timeouts(Some(Duration::from_secs(30)), None).expect("timeouts set");

    // First ~16 KiB frame fits the budget and serves.
    let u = rng.normal_vec(HEADS * 256);
    match client.call_retry(&conv_req(256, u), 64, Duration::from_millis(1)).expect("round trip")
    {
        Reply::Ok { data, .. } => assert_eq!(data.len(), HEADS * 256),
        other => panic!("in-budget request must serve: {other:?}"),
    }
    // The second breaches the cumulative budget: typed non-retryable
    // quota, then close.
    let u = rng.normal_vec(HEADS * 256);
    let (rid, reply) = {
        client.send(&conv_req(256, u)).expect("send");
        client.recv().expect("quota notice arrives")
    };
    assert_eq!(rid, 0, "quota notices are server-originated (id 0)");
    match reply {
        Reply::Quota { msg } => {
            assert!(msg.contains("budget"), "quota must name the budget: {msg}")
        }
        other => panic!("expected quota, got {other:?}"),
    }
    assert!(!Reply::Quota { msg: String::new() }.retryable());
    assert!(client.recv().is_err(), "the connection must be closed after quota");
    assert_eq!(ingress.stats().quota_closed.load(Ordering::Relaxed), 1);
}

// ---------------------------------------------------------------------------
// Reply deadline
// ---------------------------------------------------------------------------

#[test]
fn reply_deadline_times_out_retryably_and_releases_the_slot() {
    // batch_size 2: a *pair* of requests flushes immediately (fast
    // replies), a lone request waits out the 2 s window — longer than
    // the 400 ms reply deadline.
    let service = Arc::new(
        ConvService::start(
            BackendConfig::Native,
            "monarch",
            BatchPolicy { batch_size: 2, max_wait: Duration::from_secs(2) },
        )
        .expect("service starts"),
    );
    let ingress = bind(
        &service,
        IngressConfig {
            reply_deadline: Some(Duration::from_millis(400)),
            ..IngressConfig::default()
        },
    );
    let mut rng = Rng::new(0xDEAD);
    let mut client = IngressClient::connect(ingress.local_addr()).expect("client connects");
    client.set_timeouts(Some(Duration::from_secs(60)), None).expect("timeouts set");

    // Warm the bucket with a full pair (pays engine compile outside the
    // deadline-sensitive part; batch flushes on size, not the window).
    let a = client.send(&conv_req(256, rng.normal_vec(HEADS * 256))).expect("send");
    let b = client.send(&conv_req(256, rng.normal_vec(HEADS * 256))).expect("send");
    for id in [a, b] {
        let (rid, reply) = client.recv().expect("warm reply");
        assert_eq!(rid, id);
        assert!(matches!(reply, Reply::Ok { .. }), "warmup pair must serve: {reply:?}");
    }

    // A lone request stalls in the batch window past the deadline: the
    // client gets a typed, *retryable* timed_out within bounded time.
    let t0 = Instant::now();
    let reply = client
        .call(&conv_req(256, rng.normal_vec(HEADS * 256)))
        .expect("deadline round trip");
    match &reply {
        Reply::TimedOut { .. } => {}
        other => panic!("expected timed_out past the reply deadline, got {other:?}"),
    }
    assert!(reply.retryable(), "reply-deadline expiry must be retryable");
    assert!(
        t0.elapsed() < Duration::from_secs(2),
        "timed_out must beat the batch window: {:?}",
        t0.elapsed()
    );
    assert!(ingress.stats().reply_timeouts.load(Ordering::Relaxed) >= 1);

    // The connection keeps serving: one follow-up request pairs with the
    // abandoned one still queued in the batcher, flushing both fast.
    let reply = client
        .call(&conv_req(256, rng.normal_vec(HEADS * 256)))
        .expect("post-timeout round trip");
    assert!(matches!(reply, Reply::Ok { .. }), "connection must survive: {reply:?}");
    client.finish();

    // The abandoned receiver must not leak its admission slot: once the
    // batch flushes, the fleet settles to zero in flight.
    assert!(
        eventually(30, || service.fleet().stats().inflight == 0),
        "abandoned reply must still settle its fleet slot"
    );
}

// ---------------------------------------------------------------------------
// Wire-v2 streamed replies
// ---------------------------------------------------------------------------

#[test]
fn million_point_reply_streams_bit_exactly_over_wire_v2() {
    // The long-forward bucket: seq_len 65536 × 16 heads = 1,048,576
    // points per reply row — the genome-length shape the chunked reply
    // path exists for.
    const LONG: usize = 65_536;
    let service = Arc::new(
        ConvService::start(
            BackendConfig::NativeLongForward(LONG),
            "monarch",
            BatchPolicy { batch_size: 1, max_wait: Duration::from_millis(1) },
        )
        .expect("long-forward service starts"),
    );
    let ingress = bind(&service, IngressConfig::default());

    let mut rng = Rng::new(0x1_000_000);
    let u = rng.normal_vec(HEADS * LONG);

    // In-process reference through the same fleet.
    let want = service
        .call(ConvRequest { kind: ConvKind::Forward, len: LONG, streams: vec![u.clone()], chunk_tx: None })
        .expect("in-process long conv ok");
    assert_eq!(want.len(), HEADS * LONG);

    // Over the wire at v2: the reply must arrive as a streamed chunk run
    // (default chunk is 65536 points ≪ the 1,048,576-point reply) and
    // reassemble bit-exactly.
    let mut client = IngressClient::connect(ingress.local_addr()).expect("client connects");
    client.set_timeouts(Some(Duration::from_secs(300)), None).expect("timeouts set");
    match client
        .call_retry(&conv_req(LONG, u), 8, Duration::from_millis(50))
        .expect("streamed round trip")
    {
        Reply::Ok { data, .. } => {
            assert_eq!(data.len(), HEADS * LONG);
            assert_eq!(data, want, "streamed v2 reply must match in-process bit-exactly");
        }
        other => panic!("long conv over the wire failed: {other:?}"),
    }
    client.finish();

    let ist = ingress.stats();
    assert!(
        ist.chunks_out.load(Ordering::Relaxed) >= 2,
        "a ≥1M-point reply must stream as multiple chunks (got {})",
        ist.chunks_out.load(Ordering::Relaxed)
    );
    // One logical reply regardless of chunk count.
    assert!(
        eventually(5, || {
            ist.replies_out.load(Ordering::Relaxed) == ist.frames_in.load(Ordering::Relaxed)
        }),
        "a chunk run must count as one logical reply"
    );
}

#[test]
fn proxy_cut_mid_stream_is_a_typed_client_error_not_a_hang() {
    let service = single();
    let ingress = bind(
        &service,
        // Tiny chunks so a 4096-length reply (65,536 points) streams as
        // many frames and the cut lands mid-run.
        IngressConfig { stream_chunk_points: 1024, ..IngressConfig::default() },
    );
    // Requests pass clean; the reply direction is cut ~6 KB in (mid
    // second chunk frame).
    let proxy = ChaosProxy::start(
        ingress.local_addr(),
        FaultPlan::clean(),
        FaultPlan::cut_after(6_000),
    )
    .expect("proxy starts");

    let mut rng = Rng::new(0xCC2);
    let mut client = IngressClient::connect(proxy.local_addr()).expect("client connects");
    client.set_timeouts(Some(Duration::from_secs(15)), None).expect("timeouts set");
    client.send(&conv_req(4096, rng.normal_vec(HEADS * 4096))).expect("send");
    let t0 = Instant::now();
    let got = client.recv();
    assert!(
        got.is_err(),
        "a chunk run torn by a dead connection must error, got {got:?}"
    );
    assert!(t0.elapsed() < Duration::from_secs(15), "torn stream must not hang the client");
    // Server side drains cleanly too.
    assert!(eventually(15, || ingress.open_connections() == 0));
    assert!(eventually(15, || service.fleet().stats().inflight == 0));
}

// ---------------------------------------------------------------------------
// Graceful shutdown
// ---------------------------------------------------------------------------

#[test]
fn graceful_shutdown_drains_in_flight_replies() {
    // Long batch window: replies are pending when shutdown starts, and
    // must still be delivered before the connection closes.
    let service = Arc::new(
        ConvService::start(
            BackendConfig::Native,
            "monarch",
            BatchPolicy { batch_size: 8, max_wait: Duration::from_millis(300) },
        )
        .expect("service starts"),
    );
    let ingress = bind(&service, IngressConfig::default());
    let addr = ingress.local_addr();

    let mut stream = TcpStream::connect(addr).expect("client connects");
    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut rng = Rng::new(0x5D0);
    for i in 0..3u64 {
        let u = rng.normal_vec(HEADS * 256);
        stream.write_all(&wire::encode_request(1 + i, &conv_req(256, u))).expect("send");
    }
    // Give the reader time to admit all three, then shut down while they
    // are still waiting on the batch window.
    std::thread::sleep(Duration::from_millis(50));
    let t0 = Instant::now();
    ingress.shutdown(Duration::from_secs(20));
    let shutdown_wall = t0.elapsed();
    assert!(shutdown_wall < Duration::from_secs(20), "drain must finish inside grace");

    // Every in-flight reply was flushed before the close.
    for want_id in 1..=3u64 {
        let body = wire::read_frame(&mut stream)
            .expect("read ok")
            .expect("drained reply present");
        match wire::decode_reply(&body).expect("decodes") {
            (id, Reply::Ok { data, .. }) => {
                assert_eq!(id, want_id, "drained replies stay FIFO");
                assert_eq!(data.len(), HEADS * 256);
            }
            other => panic!("in-flight request lost to shutdown: {other:?}"),
        }
    }
    assert!(
        wire::read_frame(&mut stream).expect("post-drain read").is_none(),
        "connection must close cleanly after the drain"
    );
    // The acceptor is gone: new connections are refused (or reset).
    assert!(
        TcpStream::connect(addr).is_err()
            || TcpStream::connect(addr)
                .and_then(|mut s| {
                    s.set_read_timeout(Some(Duration::from_secs(5)))?;
                    let mut b = [0u8; 1];
                    use std::io::Read;
                    s.read(&mut b)
                })
                .map_or(true, |n| n == 0),
        "a shut-down ingress must not accept new work"
    );
}

// ---------------------------------------------------------------------------
// Acceptance soak: chaos + poison + parity
// ---------------------------------------------------------------------------

#[test]
fn chaos_soak_parity_with_poison_and_misbehaving_peers() {
    const CLIENTS: usize = 8;
    const PER_CLIENT: usize = 16;
    const WINDOW: usize = 4;

    let service = sharded(4, 64);
    let single_ref = ConvService::start(
        BackendConfig::Native,
        "monarch",
        BatchPolicy { batch_size: 2, max_wait: Duration::from_millis(1) },
    )
    .expect("reference service starts");

    let mut rng = Rng::new(0x50AC);
    for bucket in [256usize, 1024] {
        let k = rng.normal_vec(HEADS * bucket);
        service.set_filter(ConvKind::Forward, bucket, k.clone()).expect("fleet filter");
        single_ref.set_filter(ConvKind::Forward, bucket, k).expect("single filter");
    }

    let ingress = bind(
        &service,
        IngressConfig {
            idle_timeout: Some(Duration::from_secs(30)),
            frame_timeout: Some(Duration::from_secs(2)),
            write_timeout: Some(Duration::from_secs(10)),
            reply_deadline: Some(Duration::from_secs(30)),
            ..IngressConfig::default()
        },
    );
    let addr = ingress.local_addr();

    let stop = AtomicBool::new(false);
    let swaps = AtomicU64::new(0);
    std::thread::scope(|s| {
        // Chaos peer 1: slow loris — one clean exchange, then a stalled
        // partial frame pinning its slot until the frame deadline.
        s.spawn(|| {
            let mut loris = match TcpStream::connect(addr) {
                Ok(s) => s,
                Err(_) => return,
            };
            let _ = loris.set_read_timeout(Some(Duration::from_secs(60)));
            let mut rng = Rng::new(0x10F1);
            let u = rng.normal_vec(HEADS * 256);
            let _ = loris.write_all(&wire::encode_request(1, &conv_req(256, u)));
            let _ = wire::read_frame(&mut loris);
            let _ = loris.write_all(&[0x01, 0x02, 0x03]);
            // Hold until evicted: the next read returns the notice/EOF.
            let _ = wire::read_frame(&mut loris);
            let _ = wire::read_frame(&mut loris);
        });
        // Chaos peer 2: mid-frame cut through the proxy.
        s.spawn(|| {
            let proxy =
                match ChaosProxy::start(addr, FaultPlan::cut_after(20), FaultPlan::clean()) {
                    Ok(p) => p,
                    Err(_) => return,
                };
            if let Ok(mut s) = TcpStream::connect(proxy.local_addr()) {
                let mut rng = Rng::new(0x2C2);
                let u = rng.normal_vec(HEADS * 256);
                let _ = s.write_all(&wire::encode_request(1, &conv_req(256, u)));
                let _ = s.set_read_timeout(Some(Duration::from_secs(10)));
                let _ = wire::read_frame(&mut s);
            }
        });
        // Concurrent two-phase filter swaps on a bucket the soak never
        // routes to (epoch churn without breaking parity).
        {
            let (stop, swaps) = (&stop, &swaps);
            s.spawn(move || {
                let mut client = match IngressClient::connect(addr) {
                    Ok(c) => c,
                    Err(_) => return,
                };
                let mut rng = Rng::new(0x5A4C);
                while !stop.load(Ordering::Relaxed) {
                    let taps = rng.normal_vec(HEADS * 512);
                    let req = Request::InstallFilter { kind: 2, bucket: 512, taps };
                    if let Ok(Reply::Ok { .. }) =
                        client.call_retry(&req, 4096, Duration::from_micros(200))
                    {
                        swaps.fetch_add(1, Ordering::Relaxed);
                    }
                    std::thread::sleep(Duration::from_millis(2));
                }
                client.finish();
            });
        }
        // Poison a shard mid-soak: in-flight work on it surfaces as
        // retryable shard_died; the supervisor respawns it.
        {
            let service = &service;
            s.spawn(move || {
                std::thread::sleep(Duration::from_millis(80));
                service.fleet().poison_shard(1);
            });
        }

        // The 8 well-behaved pipelined clients.
        let mut handles = Vec::new();
        for c in 0..CLIENTS {
            let single_ref = &single_ref;
            handles.push(s.spawn(move || {
                let mut rng = Rng::new(7_000 + c as u64);
                let mut client = IngressClient::connect(addr).expect("client connects");
                client
                    .set_timeouts(Some(Duration::from_secs(120)), None)
                    .expect("timeouts set");
                let mut to_send: std::collections::VecDeque<(usize, Vec<f32>)> = (0
                    ..PER_CLIENT)
                    .map(|i| {
                        let len = if (c + i) % 4 == 0 { 1024 } else { 256 };
                        (len, rng.normal_vec(HEADS * len))
                    })
                    .collect();
                let mut queue: std::collections::VecDeque<(u64, usize, Vec<f32>)> =
                    std::collections::VecDeque::new();
                let mut done: Vec<(usize, Vec<f32>, Vec<f32>)> = Vec::new();
                let mut watermark = 0u64;
                while done.len() < PER_CLIENT {
                    while queue.len() < WINDOW {
                        match to_send.pop_front() {
                            Some((len, u)) => {
                                let id =
                                    client.send(&conv_req(len, u.clone())).expect("send");
                                queue.push_back((id, len, u));
                            }
                            None => break,
                        }
                    }
                    let (id, len, u) = queue.pop_front().expect("request outstanding");
                    let (rid, reply) = client.recv().expect("reply arrives");
                    assert_eq!(rid, id, "client {c}: lost or duplicated reply");
                    match reply {
                        Reply::Ok { epoch, session, data } => {
                            assert!(session.is_none());
                            assert!(
                                epoch >= watermark,
                                "client {c}: epoch went backwards ({epoch} < {watermark})"
                            );
                            watermark = epoch;
                            assert_eq!(data.len(), HEADS * len);
                            done.push((len, u, data));
                        }
                        r if r.retryable() => {
                            std::thread::sleep(Duration::from_micros(300));
                            to_send.push_back((len, u));
                        }
                        other => panic!("client {c}: non-retryable failure: {other:?}"),
                    }
                }
                client.finish();
                for (len, u, y) in done {
                    let want = single_ref
                        .call(ConvRequest {
                            kind: ConvKind::Forward,
                            len,
                            streams: vec![u], chunk_tx: None
                        })
                        .expect("reference conv ok");
                    assert_eq!(
                        y, want,
                        "client {c}: wire output diverged from in-process under chaos"
                    );
                }
            }));
        }
        for h in handles {
            h.join().expect("soak client thread");
        }
        stop.store(true, Ordering::Relaxed);
    });

    // The loris was evicted by a deadline while the soak progressed.
    let ist = ingress.stats();
    assert!(
        ist.read_timeouts.load(Ordering::Relaxed) >= 1,
        "the slow loris must have been evicted"
    );
    // The poisoned shard died and came back; the fleet settled.
    let stats = service.fleet().stats();
    assert!(stats.shard_deaths >= 1, "poison must register a shard death");
    assert!(
        eventually(30, || service.fleet().stats().shards.iter().all(|sh| sh.alive)),
        "the poisoned shard must respawn"
    );
    assert!(
        eventually(30, || service.fleet().stats().inflight == 0),
        "fleet must settle to zero in flight"
    );
    assert!(swaps.load(Ordering::Relaxed) >= 1, "epoch churn must have landed");
    // Zero lost or duplicated replies: every decoded request frame got
    // exactly one logical reply (notices are uncounted on both sides).
    assert!(
        eventually(10, || {
            ist.replies_out.load(Ordering::Relaxed) == ist.frames_in.load(Ordering::Relaxed)
        }),
        "replies_out must converge to frames_in: {} vs {}",
        ist.replies_out.load(Ordering::Relaxed),
        ist.frames_in.load(Ordering::Relaxed)
    );
}
