//! Integration test for `flashfftconv serve --listen` driving the real
//! compiled binary end to end: spawn it, parse the bound address off its
//! stdout handshake line, run wire round trips against it from this
//! process, then close its stdin — the `--requests 0` shutdown signal —
//! and require a graceful, successful exit with the drain marker.
//!
//! Everything is deadline-bounded: a watchdog kills the child if it
//! outlives the test budget, so a regression hangs the suite for seconds,
//! not forever.

use std::io::{BufRead, BufReader};
use std::process::{Child, Command, Stdio};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use flashfftconv::ingress::client::IngressClient;
use flashfftconv::ingress::wire::{Reply, Request};
use flashfftconv::util::Rng;

const BIN: &str = env!("CARGO_BIN_EXE_flashfftconv");
const HEADS: usize = 16;

/// Stream the child's stdout line-by-line over a channel (so the test
/// can apply its own receive deadlines instead of blocking on a pipe).
fn line_reader(child: &mut Child) -> Receiver<String> {
    let stdout = child.stdout.take().expect("stdout piped");
    let (tx, rx) = mpsc::channel();
    std::thread::spawn(move || {
        for line in BufReader::new(stdout).lines() {
            let Ok(line) = line else { break };
            if tx.send(line).is_err() {
                break;
            }
        }
    });
    rx
}

/// Kill the child if it is still running when `budget` expires. Returns
/// a guard; dropping it disarms nothing (the watchdog exits on its own
/// once the child is reaped).
fn watchdog(child: Arc<Mutex<Child>>, budget: Duration) {
    std::thread::spawn(move || {
        let deadline = Instant::now() + budget;
        loop {
            {
                let mut c = child.lock().unwrap();
                match c.try_wait() {
                    Ok(Some(_)) => return, // exited; nothing to do
                    Ok(None) if Instant::now() >= deadline => {
                        let _ = c.kill();
                        return;
                    }
                    _ => {}
                }
            }
            std::thread::sleep(Duration::from_millis(100));
        }
    });
}

/// Wait for the child with a deadline; panics (after killing it) if it
/// does not exit in time.
fn wait_bounded(child: &Arc<Mutex<Child>>, budget: Duration) -> std::process::ExitStatus {
    let deadline = Instant::now() + budget;
    loop {
        {
            let mut c = child.lock().unwrap();
            if let Ok(Some(status)) = c.try_wait() {
                return status;
            }
            if Instant::now() >= deadline {
                let _ = c.kill();
                panic!("serve binary did not exit within {budget:?}");
            }
        }
        std::thread::sleep(Duration::from_millis(50));
    }
}

#[test]
fn serve_listen_round_trips_and_drains_on_stdin_eof() {
    let mut child = Command::new(BIN)
        .args([
            "serve",
            "--listen",
            "127.0.0.1:0",
            "--requests",
            "0",
            "--shards",
            "1",
            "--max-wait-ms",
            "1",
            "--idle-ms",
            "30000",
            "--frame-ms",
            "10000",
            "--grace-ms",
            "10000",
        ])
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::inherit())
        .spawn()
        .expect("serve binary spawns");
    let stdin = child.stdin.take().expect("stdin piped");
    let lines = line_reader(&mut child);
    let child = Arc::new(Mutex::new(child));
    watchdog(Arc::clone(&child), Duration::from_secs(240));

    // Handshake: scan stdout for the machine-readable listening line.
    let mut addr = None;
    let hs_deadline = Instant::now() + Duration::from_secs(120);
    while addr.is_none() {
        let rem = hs_deadline.saturating_duration_since(Instant::now());
        match lines.recv_timeout(rem.max(Duration::from_millis(1))) {
            Ok(line) => {
                if let Some(rest) = line.strip_prefix("ingress listening on ") {
                    assert!(
                        rest.contains("(wire v2)"),
                        "handshake must advertise the wire version: {line}"
                    );
                    addr = rest.split_whitespace().next().map(str::to_string);
                }
            }
            Err(RecvTimeoutError::Timeout) => panic!("no listening handshake within 120s"),
            Err(RecvTimeoutError::Disconnected) => {
                panic!("serve binary exited before the listening handshake")
            }
        }
    }
    let addr = addr.expect("bound address parsed");

    // Real wire traffic against the spawned process: convs at two
    // lengths plus a live filter install.
    let mut rng = Rng::new(0xC11);
    let mut client = IngressClient::connect(&*addr).expect("client connects to spawned serve");
    client
        .set_timeouts(Some(Duration::from_secs(120)), Some(Duration::from_secs(30)))
        .expect("timeouts set");
    for len in [256usize, 1024, 256] {
        let u = rng.normal_vec(HEADS * len);
        let req = Request::Conv { kind: 0, len: len as u32, streams: vec![u] };
        match client
            .call_retry(&req, 64, Duration::from_millis(2))
            .expect("wire round trip against the binary")
        {
            Reply::Ok { data, .. } => assert_eq!(data.len(), HEADS * len),
            other => panic!("spawned serve rejected a conv: {other:?}"),
        }
    }
    let taps = rng.normal_vec(HEADS * 256);
    match client
        .call_retry(&Request::InstallFilter { kind: 0, bucket: 256, taps }, 64, Duration::from_millis(2))
        .expect("filter install round trip")
    {
        Reply::Ok { epoch, .. } => assert!(epoch >= 1, "install must bump the epoch"),
        other => panic!("filter install over the wire failed: {other:?}"),
    }
    client.finish();

    // Closing stdin is the shutdown signal: the binary quiesces the
    // fleet, drains the ingress, prints the marker, and exits zero.
    drop(stdin);
    let status = wait_bounded(&child, Duration::from_secs(60));
    assert!(status.success(), "serve must exit cleanly on stdin EOF: {status:?}");
    let tail: Vec<String> = lines.try_iter().collect();
    assert!(
        tail.iter().any(|l| l.contains("ingress drained and shut down")),
        "drain marker missing from serve output: {tail:?}"
    );
}

#[test]
fn serve_listen_self_driving_smoke_exits_cleanly() {
    let mut child = Command::new(BIN)
        .args(["serve", "--listen", "127.0.0.1:0", "--requests", "4", "--len", "256"])
        .stdin(Stdio::null())
        .stdout(Stdio::piped())
        .stderr(Stdio::inherit())
        .spawn()
        .expect("serve binary spawns");
    let lines = line_reader(&mut child);
    let child = Arc::new(Mutex::new(child));
    watchdog(Arc::clone(&child), Duration::from_secs(240));
    let status = wait_bounded(&child, Duration::from_secs(240));
    assert!(status.success(), "self-driving smoke must exit zero: {status:?}");
    let out: Vec<String> = lines.try_iter().collect();
    assert!(
        out.iter().any(|l| l.contains("ingress served 4/4")),
        "smoke must report a full serve: {out:?}"
    );
}
