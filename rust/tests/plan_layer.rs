//! The plan-based GEMM execution layer (`fft::plan`) held against the
//! naive oracles in `fft::`: layout and values across the full length
//! ladder (64…16384), the r2c half-spectrum path, block-sparse skipping,
//! bitwise row-block parity, engine-level planned-vs-oracle agreement,
//! and the (ignored-by-default) measured-vs-modeled order crossover.

use std::collections::BTreeMap;

use flashfftconv::bench::{bench, BenchConfig};
use flashfftconv::costmodel;
use flashfftconv::fft::{self, plan, Cpx};
use flashfftconv::runtime::{HostTensor, Runtime};
use flashfftconv::util::Rng;

fn planes(x: &[Cpx]) -> (Vec<f64>, Vec<f64>) {
    (x.iter().map(|c| c.re).collect(), x.iter().map(|c| c.im).collect())
}

#[test]
fn planned_orders_match_radix2_oracle_across_lengths() {
    // Planned order-2/3 forward == radix-2 FFT under the layout
    // permutation, and inverse round-trips, for 64..=16384.
    let mut rng = Rng::new(0xA1);
    for &n in &[64usize, 256, 1024, 4096, 16384] {
        for order in [2usize, 3] {
            let p = plan::plan(n, order).unwrap();
            assert_eq!(p.factors().len(), order, "n={n}");
            let rows = 3usize;
            let x: Vec<Cpx> =
                (0..rows * n).map(|_| Cpx::new(rng.normal(), rng.normal())).collect();
            let (mut re, mut im) = planes(&x);
            p.forward(&mut re, &mut im, rows);
            let order_vec = p.layout_order();
            for r in 0..rows {
                let full = fft::fft(&x[r * n..(r + 1) * n], false);
                for (j, &f) in order_vec.iter().enumerate() {
                    let d = (re[r * n + j] - full[f].re)
                        .abs()
                        .max((im[r * n + j] - full[f].im).abs());
                    assert!(d < 1e-8, "n={n} order={order} row={r} slot={j}: err {d}");
                }
            }
            p.inverse(&mut re, &mut im, rows);
            for (i, c) in x.iter().enumerate() {
                let d = (re[i] - c.re).abs().max((im[i] - c.im).abs());
                assert!(d < 1e-8, "n={n} order={order} roundtrip idx {i}: err {d}");
            }
        }
    }
}

#[test]
fn planned_layout_matches_monarch_orders() {
    let p2 = plan::plan(4096, 2).unwrap();
    let f = p2.factors().to_vec();
    assert_eq!(p2.layout_order(), fft::monarch_order2(f[0], f[1]));
    let p3 = plan::plan(4096, 3).unwrap();
    let f = p3.factors().to_vec();
    assert_eq!(p3.layout_order(), fft::monarch_order3(f[0], f[1], f[2]));
}

#[test]
fn planned_r2c_matches_naive_oracle_across_lengths() {
    // r2c half spectra == leading rfft_full bins, and c2r round-trips,
    // for 64..=16384 at every implemented order.
    let mut rng = Rng::new(0xA2);
    for &n in &[64usize, 128, 512, 2048, 4096, 16384] {
        for order in [1usize, 2, 3] {
            if order == 1 && n > 512 {
                // An order-1 plan is one dense (n/2)² DFT matrix; past
                // n=512 that is pure memory burn (the registry caches it
                // for the process lifetime) with no added coverage.
                continue;
            }
            let rp = plan::real_plan(n, order).unwrap();
            let rows = 2usize;
            let x: Vec<f64> = (0..rows * n).map(|_| rng.normal()).collect();
            let (sre, sim) = rp.rfft_rows(&x, rows);
            for r in 0..rows {
                let full = fft::rfft_full(&x[r * n..(r + 1) * n]);
                for k in 0..rp.bins() {
                    let d = (sre[r * rp.bins() + k] - full[k].re)
                        .abs()
                        .max((sim[r * rp.bins() + k] - full[k].im).abs());
                    assert!(d < 1e-8, "n={n} order={order} row={r} bin={k}: err {d}");
                }
            }
            let y = rp.irfft_rows(&sre, &sim, rows);
            for (a, b) in y.iter().zip(&x) {
                assert!((a - b).abs() < 1e-8, "n={n} order={order} roundtrip");
            }
        }
    }
}

#[test]
fn planned_conv_matches_fft_conv_and_blocking_is_bitwise() {
    let mut rng = Rng::new(0xA3);
    let n = 1024usize;
    let (rows, heads) = (8usize, 4usize);
    let rp = plan::real_plan(n, 2).unwrap();
    let u: Vec<f64> = (0..rows * n).map(|_| rng.normal()).collect();
    let kbank: Vec<f64> = (0..heads * n).map(|_| rng.normal()).collect();
    let (kre, kim) = rp.rfft_rows(&kbank, heads);
    let y = rp.conv_rows(&u, rows, &kre, &kim, |r| r % heads);
    // Against the naive fused-FFT oracle.
    for r in 0..rows {
        let want = fft::fft_conv(
            &u[r * n..(r + 1) * n],
            &kbank[(r % heads) * n..(r % heads + 1) * n],
        );
        let err = fft::max_abs_diff(&y[r * n..(r + 1) * n], &want);
        assert!(err < 1e-8, "row {r}: err {err}");
    }
    // Row-block splits must be bitwise identical to the single batch —
    // the property that makes parallel row fan-out deterministic.
    for split in [1usize, 2, 3, 8] {
        let blocks = flashfftconv::util::pool::row_blocks(rows, split);
        let mut parts: Vec<f64> = Vec::with_capacity(rows * n);
        for blk in blocks {
            let piece = rp.conv_rows(
                &u[blk.start * n..blk.end * n],
                blk.len(),
                &kre,
                &kim,
                |i| (blk.start + i) % heads,
            );
            parts.extend_from_slice(&piece);
        }
        assert!(
            y.iter().zip(&parts).all(|(a, b)| a.to_bits() == b.to_bits()),
            "split={split}: block fan-out changed bits"
        );
    }
}

#[test]
fn planned_block_inverse_matches_naive_block_oracle() {
    let mut rng = Rng::new(0xA4);
    for &(n1, n2, kr, kc) in
        &[(8usize, 8usize, 4usize, 2usize), (8, 4, 2, 3), (16, 16, 16, 16), (8, 16, 1, 1)]
    {
        let n = n1 * n2;
        let p = plan::FftPlan::new(n, vec![n1, n2]).unwrap();
        let mut spec: Vec<Cpx> =
            (0..n).map(|_| Cpx::new(rng.normal(), rng.normal())).collect();
        for r in 0..n1 {
            for c in 0..n2 {
                if r >= kr || c >= kc {
                    spec[r * n2 + c] = Cpx::ZERO;
                }
            }
        }
        // Batched (rows = 2) to exercise the per-row loop.
        let two: Vec<Cpx> = spec.iter().chain(spec.iter()).copied().collect();
        let (mut re, mut im) = planes(&two);
        p.inverse2_block(&mut re, &mut im, 2, kr, kc);
        let want = fft::monarch_ifft2_block(&spec, n1, n2, kr, kc);
        for rep in 0..2 {
            for (j, w) in want.iter().enumerate() {
                let d = (re[rep * n + j] - w.re).abs().max((im[rep * n + j] - w.im).abs());
                assert!(d < 1e-10, "({n1},{n2},{kr},{kc}) rep {rep} slot {j}: err {d}");
            }
        }
    }
}

/// Manifest for a minimal monarch conv artifact with a pinned thread
/// count (mirrors the fleet's conv artifacts, no fixtures needed).
fn conv_manifest(kind: &str, n: usize, threads: usize, extra: &str) -> String {
    format!(
        "version 1\nartifact cx\nhlo cx.hlo.txt\nmeta group conv\nmeta kind {kind}\n\
         meta variant monarch\nmeta seq_len {n}\nmeta batch 2\nmeta heads 4\n\
         meta conv_threads {threads}\n{extra}\
         input u f32 2,4,{n} runtime\ninput k f32 4,{n} runtime\noutput y f32 2,4,{n}\nend\n"
    )
}

#[test]
fn planned_engine_matches_naive_oracle_and_is_blocking_invariant() {
    // The planned engine against the naive radix-2 oracle at both
    // cost-model orders (circular n=256 -> order 2; causal n=64 ->
    // fft_len 128 -> order 3), plus bitwise parity across worker counts.
    for (kind, n) in [("conv_fwd", 256usize), ("conv_causal", 64)] {
        let mut outs: Vec<Vec<f32>> = vec![];
        for threads in [1usize, 4] {
            let rt =
                Runtime::native_from(&conv_manifest(kind, n, threads, ""), BTreeMap::new())
                    .unwrap();
            let mut rng = Rng::new(0xB0B);
            let u = rng.normal_vec(2 * 4 * n);
            let k = rng.normal_vec(4 * n);
            let y = rt
                .load("cx")
                .unwrap()
                .call(&[
                    HostTensor::f32(u.clone(), &[2, 4, n]),
                    HostTensor::f32(k.clone(), &[4, n]),
                ])
                .unwrap();
            let y = y[0].as_f32().to_vec();
            // Oracle check on every row.
            for bi in 0..2 {
                for hi in 0..4 {
                    let off = (bi * 4 + hi) * n;
                    let urow: Vec<f64> =
                        u[off..off + n].iter().map(|&v| v as f64).collect();
                    let krow: Vec<f64> =
                        k[hi * n..(hi + 1) * n].iter().map(|&v| v as f64).collect();
                    let want = if kind == "conv_causal" {
                        fft::causal_conv(&urow, &krow)
                    } else {
                        fft::fft_conv(&urow, &krow)
                    };
                    for (t, w) in want.iter().enumerate() {
                        assert!(
                            (y[off + t] as f64 - w).abs() < 1e-3,
                            "{kind} n={n} threads={threads} row ({bi},{hi}) t {t}"
                        );
                    }
                }
            }
            outs.push(y);
        }
        assert_eq!(outs[0], outs[1], "{kind}: worker count changed results (bitwise)");
    }
}

#[test]
fn planned_sparse_engine_matches_block_oracle() {
    // Block-sparse planned engine vs the naive masked-spectrum oracle
    // (the same parity the fleet's golden checks at n=1024 rely on).
    let n = 256usize;
    let fs = fft::monarch_factors(n, 2);
    let (n1, n2) = (fs[0], fs[1]);
    let (kr, kc) = (n1 / 2, n2 / 2);
    let extra = format!("meta order 2\nmeta keep_rows {kr}\nmeta keep_cols {kc}\n");
    let rt = Runtime::native_from(&conv_manifest("conv_fwd", n, 2, &extra), BTreeMap::new())
        .unwrap();
    let mut rng = Rng::new(0xB0C);
    let u = rng.normal_vec(2 * 4 * n);
    let k = rng.normal_vec(4 * n);
    let y = rt
        .load("cx")
        .unwrap()
        .call(&[HostTensor::f32(u.clone(), &[2, 4, n]), HostTensor::f32(k.clone(), &[4, n])])
        .unwrap();
    let y = y[0].as_f32().to_vec();
    let pat = flashfftconv::coordinator::sparse::SparsityPattern::new(n1, n2, kr, kc).unwrap();
    for bi in 0..2 {
        for hi in 0..4 {
            let off = (bi * 4 + hi) * n;
            let krow: Vec<f64> = k[hi * n..(hi + 1) * n].iter().map(|&v| v as f64).collect();
            let kf = fft::rfft_full(&krow);
            let mut re: Vec<f32> = kf.iter().map(|z| z.re as f32).collect();
            let mut im: Vec<f32> = kf.iter().map(|z| z.im as f32).collect();
            pat.apply_spectrum(&mut re, &mut im);
            let spec_row: Vec<Cpx> = re
                .iter()
                .zip(&im)
                .map(|(&r, &i)| Cpx::new(r as f64, i as f64))
                .collect();
            let urow: Vec<f64> = u[off..off + n].iter().map(|&v| v as f64).collect();
            let want = fft::fft_conv_spectrum(&urow, &spec_row);
            for (t, w) in want.iter().enumerate() {
                assert!(
                    (y[off + t] as f64 - w).abs() < 1e-3,
                    "sparse row ({bi},{hi}) t {t}: {} vs {w}",
                    y[off + t]
                );
            }
        }
    }
}

#[test]
fn tuned_dispatch_is_deterministic_and_model_mode_matches_the_prior() {
    // The autotuner behind engine dispatch: model mode reproduces the
    // analytic §3.2 choice exactly, measured mode returns a dispatchable
    // order whose winner is cached (repeat lookups agree, at most one
    // measurement per key, strategy named after the live kernel tier).
    // Rows 256 → a rows-class no other test in this binary touches (the
    // engine tests above run at rows 8), so the cache assertions are
    // isolated.
    use flashfftconv::fft::tune;
    for &fft_len in &[128usize, 512, 2048, 8192] {
        let analytic = costmodel::best_native_order(fft_len);
        assert_eq!(
            tune::tuned_order_with(fft_len, 256, tune::TuneMode::Model),
            analytic,
            "fft_len {fft_len}: model mode diverged from the analytic prior"
        );
        let choice = tune::tuned_choice(fft_len, 256).expect("decided key is cached");
        assert!(!choice.measured, "model mode must never measure");
        // Model-mode decisions stay pinned on cache hits even when a
        // later caller asks under measured mode — dispatch is stable for
        // the process lifetime.
        assert_eq!(tune::tuned_order_with(fft_len, 256, tune::TuneMode::Measure), analytic);
    }
    // Measured mode on fresh keys (rows 2048 → another dedicated class).
    for &fft_len in &[256usize, 1024] {
        let first = tune::tuned_order(fft_len, 2048);
        assert!(
            (2..=costmodel::MAX_NATIVE_ORDER).contains(&first),
            "fft_len {fft_len}: undispatchable order {first}"
        );
        for _ in 0..3 {
            assert_eq!(tune::tuned_order(fft_len, 2048), first, "fft_len {fft_len}");
        }
        let choice = tune::tuned_choice(fft_len, 2048).expect("cached after first use");
        assert_eq!(choice.order, first);
        assert!(choice.measure_runs <= 1, "re-measured: {choice:?}");
        assert!(
            choice.strategy.ends_with(&format!("-o{first}")),
            "strategy {:?} does not name order {first}",
            choice.strategy
        );
    }
}

#[test]
fn f32_precision_engine_tracks_the_f64_engine_and_the_oracle() {
    // `meta precision f32` flips the dense Monarch engine onto the
    // tolerance-gated single-precision plan tier; outputs must track
    // both the f64 engine and the radix-2 oracle within an
    // accumulation-scaled absolute gate (conv outputs of O(1) inputs are
    // O(√n); f32 rounding grows the same way).
    for (kind, n) in [("conv_fwd", 256usize), ("conv_causal", 64)] {
        let mut rng = Rng::new(0xF32);
        let u = rng.normal_vec(2 * 4 * n);
        let k = rng.normal_vec(4 * n);
        let run = |extra: &str| -> Vec<f32> {
            let rt = Runtime::native_from(&conv_manifest(kind, n, 1, extra), BTreeMap::new())
                .unwrap();
            let y = rt
                .load("cx")
                .unwrap()
                .call(&[
                    HostTensor::f32(u.clone(), &[2, 4, n]),
                    HostTensor::f32(k.clone(), &[4, n]),
                ])
                .unwrap();
            y[0].as_f32().to_vec()
        };
        let y64 = run("");
        let y32 = run("meta precision f32\n");
        let gate = 1e-5 * (n as f64) + 1e-4;
        for (t, (&a, &b)) in y32.iter().zip(&y64).enumerate() {
            assert!(
                (a as f64 - b as f64).abs() < gate,
                "{kind} n={n} t={t}: f32 tier {a} vs f64 tier {b}"
            );
        }
        for bi in 0..2 {
            for hi in 0..4 {
                let off = (bi * 4 + hi) * n;
                let urow: Vec<f64> = u[off..off + n].iter().map(|&v| v as f64).collect();
                let krow: Vec<f64> =
                    k[hi * n..(hi + 1) * n].iter().map(|&v| v as f64).collect();
                let want = if kind == "conv_causal" {
                    fft::causal_conv(&urow, &krow)
                } else {
                    fft::fft_conv(&urow, &krow)
                };
                for (t, w) in want.iter().enumerate() {
                    assert!(
                        (y32[off + t] as f64 - w).abs() < gate,
                        "{kind} n={n} row ({bi},{hi}) t {t}: f32 tier vs oracle"
                    );
                }
            }
        }
    }
    // The fleet-wide opt-in (BackendConfig::NativeConvF32) builds and
    // serves: every dense artifact re-plans through the gated f32 tier.
    let rt = Runtime::native_conv_f32().expect("f32 fleet constructs");
    let n = 256usize;
    let mut rng = Rng::new(0xF33);
    let u = rng.normal_vec(2 * 16 * n);
    let k = rng.normal_vec(16 * n);
    let y = rt
        .load("conv_fwd_monarch_n256")
        .unwrap()
        .call(&[HostTensor::f32(u.clone(), &[2, 16, n]), HostTensor::f32(k.clone(), &[16, n])])
        .unwrap();
    let y = y[0].as_f32();
    let gate = 1e-5 * (n as f64) + 1e-4;
    for bi in 0..2 {
        for hi in 0..16 {
            let off = (bi * 16 + hi) * n;
            let urow: Vec<f64> = u[off..off + n].iter().map(|&v| v as f64).collect();
            let krow: Vec<f64> = k[hi * n..(hi + 1) * n].iter().map(|&v| v as f64).collect();
            let want = fft::fft_conv(&urow, &krow);
            for (t, w) in want.iter().enumerate() {
                assert!(
                    (y[off + t] as f64 - w).abs() < gate,
                    "f32 fleet row ({bi},{hi}) t {t}"
                );
            }
        }
    }
}

/// Measured-vs-modeled sanity: the calibrated §3.2 cost model's order
/// choice (2..=4 since the order-4 cap raise) should match the *measured*
/// crossover of the planned engine within one bucket of the length
/// ladder — this probe is the calibration input for `costmodel::CPU`.
/// Timing-sensitive, so ignored by default — run with
/// `cargo test --release --test plan_layer -- --ignored`.
#[test]
#[ignore = "timing-sensitive perf probe; run explicitly with -- --ignored"]
fn measured_order_crossover_matches_cost_model_within_one_bucket() {
    let ladder: Vec<usize> = (7..=16).map(|lg| 1usize << lg).collect(); // 128..65536
    let cfg = BenchConfig {
        warmup: 1,
        iters: 5,
        max_time: std::time::Duration::from_secs(4),
    };
    let orders = [2usize, 3, 4];
    let rows = 8usize;
    let mut rng = Rng::new(0xC0);
    let mut modeled = vec![];
    let mut measured = vec![];
    let mut ws = fft::workspace::ConvWorkspace::new();
    for &fft_len in &ladder {
        modeled.push(costmodel::best_native_order(fft_len));
        let n = fft_len / 2; // conv seq_len whose causal FFT is fft_len
        let x: Vec<f64> = (0..rows * fft_len)
            .map(|i| if i % fft_len < n { rng.normal() } else { 0.0 })
            .collect();
        let kb: Vec<f64> = (0..fft_len).map(|i| if i < n { rng.normal() } else { 0.0 }).collect();
        let mut y = vec![0.0f64; rows * fft_len];
        let mut times = vec![];
        for &order in &orders {
            let rp = plan::real_plan(fft_len, order).unwrap();
            let (kre, kim) = rp.rfft_rows(&kb, 1);
            let r = bench(&format!("planned_o{order}_m{fft_len}"), &cfg, || {
                rp.conv_rows_into(&x, rows, &kre, &kim, |_| 0, &mut y, &mut ws);
                std::hint::black_box(&y);
            });
            times.push(r.median_ns);
        }
        let best = (0..orders.len()).min_by(|&a, &b| times[a].total_cmp(&times[b])).unwrap();
        measured.push(orders[best]);
    }
    eprintln!("fft_len: modeled vs measured");
    for (i, &m) in ladder.iter().enumerate() {
        eprintln!("  {m:>6}: p={} vs p={}", modeled[i], measured[i]);
    }
    for i in 0..ladder.len() {
        let ok = measured[i] == modeled[i]
            || (i > 0 && measured[i - 1] == modeled[i])
            || (i + 1 < ladder.len() && measured[i + 1] == modeled[i]);
        assert!(
            ok,
            "fft_len {}: modeled order {} not within one bucket of measured {:?}",
            ladder[i], modeled[i], measured
        );
    }
}
