//! Counting-allocator proof of the zero-alloc serving contract: once a
//! `ConvWorkspace` is warm, every plan executor (`forward_ws` /
//! `inverse_ws` / `inverse2_block_ws` / `rfft_rows_into` /
//! `irfft_rows_into` / `conv_rows_into`) runs without touching the heap.
//!
//! This binary installs a counting global allocator, so it deliberately
//! holds exactly one `#[test]`: concurrent test threads in the same
//! binary would pollute the allocation counters.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use flashfftconv::fft::plan::{self, FftPlan};
use flashfftconv::fft::workspace::ConvWorkspace;
use flashfftconv::util::Rng;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::SeqCst);
        System.alloc(l)
    }
    unsafe fn alloc_zeroed(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::SeqCst);
        System.alloc_zeroed(l)
    }
    unsafe fn realloc(&self, p: *mut u8, l: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::SeqCst);
        System.realloc(p, l, new_size)
    }
    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
}

#[global_allocator]
static A: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOCS.load(Ordering::SeqCst)
}

#[test]
fn plan_executors_are_zero_alloc_at_steady_state() {
    let mut rng = Rng::new(0xA110C);
    let rows = 4usize;

    // Plans covering order 2/3 complex, r2c at two lengths, and the
    // block-sparse inverse (all built before counting starts).
    let p2 = plan::plan(256, 2).unwrap();
    let p3 = plan::plan(512, 3).unwrap();
    let rp = plan::real_plan(1024, 2).unwrap();
    let rp_small = plan::real_plan(128, 3).unwrap();
    let bp = FftPlan::new(256, vec![16, 16]).unwrap();

    // Every input/output buffer is owned by the test and reused, so the
    // only heap traffic the measured loop *could* produce is the plan
    // executors' own.
    let re0: Vec<f64> = (0..rows * 256).map(|_| rng.normal()).collect();
    let im0: Vec<f64> = (0..rows * 256).map(|_| rng.normal()).collect();
    let re3_0: Vec<f64> = (0..rows * 512).map(|_| rng.normal()).collect();
    let im3_0: Vec<f64> = (0..rows * 512).map(|_| rng.normal()).collect();
    let u: Vec<f64> = (0..rows * 1024).map(|_| rng.normal()).collect();
    let us: Vec<f64> = (0..rows * 128).map(|_| rng.normal()).collect();
    let kb: Vec<f64> = (0..1024).map(|_| rng.normal()).collect();
    let kbs: Vec<f64> = (0..128).map(|_| rng.normal()).collect();
    let (kre, kim) = rp.rfft_rows(&kb, 1);
    let (kre_s, kim_s) = rp_small.rfft_rows(&kbs, 1);

    let mut re = re0.clone();
    let mut im = im0.clone();
    let mut re3 = re3_0.clone();
    let mut im3 = im3_0.clone();
    let mut reb = re0.clone();
    let mut imb = im0.clone();
    let mut sre = vec![0.0f64; rows * rp.bins()];
    let mut sim = vec![0.0f64; rows * rp.bins()];
    let mut y = vec![0.0f64; rows * 1024];
    let mut ys = vec![0.0f64; rows * 128];

    let mut ws = ConvWorkspace::new();
    // Mixed lengths and orders interleave through ONE workspace — the
    // serving shape (one workspace per shard worker, many buckets).
    let mut run = |ws: &mut ConvWorkspace| {
        re.copy_from_slice(&re0);
        im.copy_from_slice(&im0);
        p2.forward_ws(&mut re, &mut im, rows, ws);
        p2.inverse_ws(&mut re, &mut im, rows, ws);
        re3.copy_from_slice(&re3_0);
        im3.copy_from_slice(&im3_0);
        p3.forward_ws(&mut re3, &mut im3, rows, ws);
        p3.inverse_ws(&mut re3, &mut im3, rows, ws);
        rp.rfft_rows_into(&u, rows, &mut sre, &mut sim, ws);
        rp.irfft_rows_into(&sre, &sim, rows, &mut y, ws);
        rp.conv_rows_into(&u, rows, &kre, &kim, |_| 0, &mut y, ws);
        rp_small.conv_rows_into(&us, rows, &kre_s, &kim_s, |_| 0, &mut ys, ws);
        reb.copy_from_slice(&re0);
        imb.copy_from_slice(&im0);
        bp.inverse2_block_ws(&mut reb, &mut imb, rows, 8, 8, ws);
    };

    // Warm pass: cold misses populate the workspace's free lists.
    run(&mut ws);
    ws.reset();

    let before = allocs();
    for _ in 0..5 {
        run(&mut ws);
    }
    let delta = allocs() - before;
    let stats = ws.stats();
    assert_eq!(
        delta, 0,
        "steady-state plan execution must perform zero heap allocations \
         (counted {delta} over 5 mixed-shape passes; workspace stats {stats:?})"
    );
    assert_eq!(stats.allocs, 0, "no cold misses after warm-up: {stats:?}");
    assert!(stats.takes > 0 && stats.peak_bytes > 0, "workspace was exercised: {stats:?}");

    // Sanity: the loop actually computed something.
    assert!(y.iter().any(|&v| v != 0.0));
    assert!(ys.iter().any(|&v| v != 0.0));
}
