//! Wire-codec properties (satellite of the ingress PR): every frame type
//! round-trips bit-exactly through encode/decode, and malformed input —
//! truncations, oversized length words, wrong version bytes, unknown
//! opcodes/statuses, trailing bytes, corrupt count fields, random junk —
//! is rejected with a typed [`WireError`] without panicking or
//! allocating unbounded memory.

use std::io::Read;

use flashfftconv::ingress::wire::{
    self, Reply, Request, WireError, MAX_FRAME, MIN_FRAME, WIRE_VERSION,
};
use flashfftconv::prop::{default_cases, forall, gen};
use flashfftconv::util::Rng;

// ---------------------------------------------------------------------------
// Generators
// ---------------------------------------------------------------------------

fn gen_tokens(rng: &mut Rng, max: usize) -> Vec<i32> {
    (0..gen::index(rng, 0, max)).map(|_| rng.range(-10_000, 10_000) as i32).collect()
}

fn gen_msg(rng: &mut Rng) -> String {
    (0..gen::index(rng, 0, 48)).map(|_| (b'a' + rng.below(26) as u8) as char).collect()
}

fn gen_request(rng: &mut Rng) -> Request {
    match rng.below(6) {
        0 => {
            let kind = rng.below(3) as u8;
            let n_streams = if kind == 1 { 3usize } else { 1 };
            let m = gen::index(rng, 0, 64);
            let streams = (0..n_streams).map(|_| rng.normal_vec(m)).collect();
            Request::Conv { kind, len: rng.below(4096) as u32, streams }
        }
        1 => Request::LmLogits { tokens: gen_tokens(rng, 64) },
        2 => Request::OpenSession { prompt: gen_tokens(rng, 64) },
        3 => Request::Step {
            session: rng.next_u64(),
            token: rng.range(-10_000, 10_000) as i32,
        },
        4 => Request::CloseSession { session: rng.next_u64() },
        _ => Request::InstallFilter {
            kind: rng.below(3) as u8,
            bucket: rng.below(8192) as u32,
            taps: rng.normal_vec(gen::index(rng, 0, 64)),
        },
    }
}

fn gen_reply(rng: &mut Rng) -> Reply {
    match rng.below(10) {
        0 => Reply::Ok {
            epoch: rng.next_u64(),
            session: if rng.chance(0.5) { Some(rng.next_u64()) } else { None },
            data: rng.normal_vec(gen::index(rng, 0, 64)),
        },
        1 => Reply::Busy,
        2 => Reply::ShardDied,
        3 => Reply::Failed { msg: gen_msg(rng) },
        4 => Reply::SessionLost,
        5 => Reply::Shutdown,
        6 => Reply::BadRequest { msg: gen_msg(rng) },
        7 => Reply::OkChunk {
            epoch: rng.next_u64(),
            seq: rng.below(1 << 20) as u32,
            fin: rng.chance(0.5),
            data: rng.normal_vec(gen::index(rng, 0, 64)),
        },
        8 => Reply::TimedOut { msg: gen_msg(rng) },
        _ => Reply::Quota { msg: gen_msg(rng) },
    }
}

/// Split an encoded frame into (validated length word, body).
fn split(frame: &[u8]) -> (usize, &[u8]) {
    let len = u32::from_le_bytes(frame[..4].try_into().unwrap()) as usize;
    assert_eq!(len, frame.len() - 4, "length prefix must cover exactly the body");
    wire::check_frame_len(len).expect("encoded frames stay within protocol bounds");
    (len, &frame[4..])
}

// ---------------------------------------------------------------------------
// Round trips
// ---------------------------------------------------------------------------

#[test]
fn every_request_round_trips_bit_exactly() {
    forall(
        "request round trip",
        0x11A1,
        default_cases().max(64),
        |rng| (rng.next_u64(), gen_request(rng)),
        |(id, req)| {
            let frame = wire::encode_request(*id, req);
            let (_, body) = split(&frame);
            let (rid, back) = wire::decode_request(body).expect("valid frame decodes");
            rid == *id && back == *req
        },
    );
}

#[test]
fn every_reply_round_trips_bit_exactly() {
    forall(
        "reply round trip",
        0x11A2,
        default_cases().max(64),
        |rng| (rng.next_u64(), gen_reply(rng)),
        |(id, reply)| {
            let frame = wire::encode_reply(*id, reply);
            let (_, body) = split(&frame);
            let (rid, back) = wire::decode_reply(body).expect("valid frame decodes");
            rid == *id && back == *reply
        },
    );
}

#[test]
fn read_frame_round_trips_pipelined_frames_then_clean_eof() {
    let mut rng = Rng::new(0x11A3);
    let frames: Vec<(u64, Request)> =
        (0..8).map(|_| (rng.next_u64(), gen_request(&mut rng))).collect();
    let mut stream = Vec::new();
    for (id, req) in &frames {
        stream.extend_from_slice(&wire::encode_request(*id, req));
    }
    let mut r = std::io::Cursor::new(stream);
    for (id, req) in &frames {
        let body = wire::read_frame(&mut r).expect("read ok").expect("frame present");
        let (rid, back) = wire::decode_request(&body).expect("decodes");
        assert_eq!(rid, *id);
        assert_eq!(&back, req);
    }
    assert!(
        wire::read_frame(&mut r).expect("clean eof is not an error").is_none(),
        "EOF between frames must read as None"
    );
}

// ---------------------------------------------------------------------------
// Rejection: every malformed shape errors, none panic
// ---------------------------------------------------------------------------

#[test]
fn any_strict_prefix_of_a_valid_frame_is_rejected() {
    // Counts are explicit in the byte stream, so removing trailing bytes
    // can only starve a later read: every strict prefix must error (and
    // must not panic).
    forall(
        "strict prefixes rejected",
        0x11B1,
        default_cases(),
        |rng| (rng.next_u64(), gen_request(rng), gen_reply(rng)),
        |(id, req, reply)| {
            let body = wire::encode_request(*id, req)[4..].to_vec();
            for cut in 0..body.len() {
                if wire::decode_request(&body[..cut]).is_ok() {
                    return false;
                }
            }
            let body = wire::encode_reply(*id, reply)[4..].to_vec();
            for cut in 0..body.len() {
                if wire::decode_reply(&body[..cut]).is_ok() {
                    return false;
                }
            }
            true
        },
    );
}

#[test]
fn trailing_bytes_are_rejected() {
    forall(
        "trailing bytes rejected",
        0x11B2,
        default_cases(),
        |rng| (rng.next_u64(), gen_request(rng)),
        |(id, req)| {
            let mut body = wire::encode_request(*id, req)[4..].to_vec();
            body.push(0);
            wire::decode_request(&body) == Err(WireError::BadPayload("trailing bytes"))
        },
    );
}

#[test]
fn wrong_version_byte_is_rejected_as_bad_version() {
    let body = wire::encode_request(7, &Request::CloseSession { session: 1 })[4..].to_vec();
    for v in [0u8, WIRE_VERSION + 1, 0xFF] {
        let mut b = body.clone();
        b[0] = v;
        assert_eq!(wire::decode_request(&b), Err(WireError::BadVersion(v)));
        assert_eq!(wire::decode_reply(&b), Err(WireError::BadVersion(v)));
        assert_eq!(wire::frame_version(&b), Err(WireError::BadVersion(v)));
    }
    assert_eq!(body[0], WIRE_VERSION, "encoder must stamp the supported version");
    assert_eq!(wire::frame_version(&body), Ok(WIRE_VERSION));
    assert_eq!(wire::frame_version(&[]), Err(WireError::Truncated));
}

// ---------------------------------------------------------------------------
// Version negotiation: v1 compatibility and v2-only status downgrades
// ---------------------------------------------------------------------------

#[test]
fn v1_frames_still_round_trip_and_carry_their_version() {
    forall(
        "v1 compatibility round trip",
        0x11C1,
        default_cases().max(64),
        |rng| (rng.next_u64(), gen_request(rng)),
        |(id, req)| {
            let frame = wire::encode_request_v(*id, req, 1);
            let (_, body) = split(&frame);
            if wire::frame_version(body) != Ok(1) {
                return false;
            }
            let (rid, back) = wire::decode_request(body).expect("v1 frame decodes");
            rid == *id && back == *req
        },
    );
}

#[test]
fn v2_only_statuses_downgrade_at_v1_and_stay_typed_at_v2() {
    let timed = Reply::TimedOut { msg: "deadline".into() };
    let quota = Reply::Quota { msg: "budget".into() };
    let chunk = Reply::OkChunk { epoch: 3, seq: 0, fin: true, data: vec![1.0] };

    // At v2 each status survives encode/decode as itself.
    for r in [&timed, &quota, &chunk] {
        let (_, body) = {
            let f = wire::encode_reply_v(9, r, 2);
            (0, f[4..].to_vec())
        };
        let (_, back) = wire::decode_reply(&body).expect("v2 status decodes");
        assert_eq!(&back, r);
    }

    // At v1 the encoder downgrades: timed_out stays *retryable* (busy),
    // quota and chunk become typed failures a v1 client can decode.
    let (_, back) = wire::decode_reply(&wire::encode_reply_v(9, &timed, 1)[4..]).unwrap();
    assert_eq!(back, Reply::Busy, "timed_out must stay retryable at v1");
    assert!(back.retryable());
    let (_, back) = wire::decode_reply(&wire::encode_reply_v(9, &quota, 1)[4..]).unwrap();
    assert!(
        matches!(&back, Reply::Failed { msg } if msg.contains("quota")),
        "quota must downgrade to a failed naming the cause, got {back:?}"
    );
    let (_, back) = wire::decode_reply(&wire::encode_reply_v(9, &chunk, 1)[4..]).unwrap();
    assert!(
        matches!(&back, Reply::Failed { msg } if msg.contains("v2")),
        "ok_chunk must downgrade to a failed naming the fix, got {back:?}"
    );

    // The downgraded frames carry version byte 1 (a v1 client's range).
    for r in [&timed, &quota, &chunk] {
        assert_eq!(wire::frame_version(&wire::encode_reply_v(9, r, 1)[4..]), Ok(1));
    }

    // Retryability contract across the full status set.
    assert!(Reply::Busy.retryable());
    assert!(Reply::ShardDied.retryable());
    assert!(timed.retryable());
    assert!(!quota.retryable());
    assert!(!Reply::SessionLost.retryable());
    assert!(!Reply::Shutdown.retryable());
}

#[test]
fn unknown_opcode_and_status_are_rejected() {
    let mut body = wire::encode_request(7, &Request::CloseSession { session: 1 })[4..].to_vec();
    body[1] = 99;
    assert_eq!(wire::decode_request(&body), Err(WireError::BadOpcode(99)));
    let mut body = wire::encode_reply(7, &Reply::Busy)[4..].to_vec();
    body[1] = 200;
    assert_eq!(wire::decode_reply(&body), Err(WireError::BadStatus(200)));
}

#[test]
fn oversized_and_undersized_length_words_are_rejected_before_allocation() {
    assert_eq!(wire::check_frame_len(MAX_FRAME + 1), Err(WireError::Oversized(MAX_FRAME + 1)));
    assert_eq!(wire::check_frame_len(MIN_FRAME - 1), Err(WireError::Oversized(MIN_FRAME - 1)));
    assert_eq!(wire::check_frame_len(0), Err(WireError::Oversized(0)));
    assert!(wire::check_frame_len(MIN_FRAME).is_ok());
    assert!(wire::check_frame_len(MAX_FRAME).is_ok());

    // A stream claiming a 4 GiB frame errors out of read_frame without
    // the body ever being allocated.
    let huge = (u32::MAX).to_le_bytes();
    let err = wire::read_frame(&mut std::io::Cursor::new(huge.to_vec()))
        .expect_err("oversized length must be an error");
    assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
}

#[test]
fn corrupt_count_fields_error_without_huge_allocation() {
    // An lm_logits body whose count word claims u32::MAX tokens but
    // carries none: `counted()` checks against the remaining bytes before
    // reserving, so this must fail fast as Truncated.
    let mut body = vec![WIRE_VERSION, 2];
    body.extend_from_slice(&7u64.to_le_bytes());
    body.extend_from_slice(&u32::MAX.to_le_bytes());
    assert_eq!(wire::decode_request(&body), Err(WireError::Truncated));

    // Same for the f32 payload of an ok reply.
    let mut body = vec![WIRE_VERSION, 0];
    body.extend_from_slice(&7u64.to_le_bytes());
    body.extend_from_slice(&0u64.to_le_bytes()); // epoch
    body.push(0); // no session id
    body.extend_from_slice(&0xFFFF_FF00u32.to_le_bytes());
    assert_eq!(wire::decode_reply(&body), Err(WireError::Truncated));
}

#[test]
fn semantically_invalid_payloads_are_rejected() {
    // Conv kind out of range.
    let mut body = vec![WIRE_VERSION, 1];
    body.extend_from_slice(&1u64.to_le_bytes());
    body.push(3); // kind 3 does not exist
    assert!(matches!(wire::decode_request(&body), Err(WireError::BadPayload(_))));

    // Gated conv with the wrong stream count.
    let frame = wire::encode_request(
        1,
        &Request::Conv { kind: 1, len: 8, streams: vec![vec![0.0; 8]] },
    );
    assert!(matches!(wire::decode_request(&frame[4..]), Err(WireError::BadPayload(_))));

    // Ok reply with a session flag that is neither 0 nor 1.
    let mut body = vec![WIRE_VERSION, 0];
    body.extend_from_slice(&1u64.to_le_bytes());
    body.extend_from_slice(&0u64.to_le_bytes());
    body.push(2);
    assert!(matches!(wire::decode_reply(&body), Err(WireError::BadPayload(_))));

    // Failed reply with a non-UTF-8 message.
    let mut body = vec![WIRE_VERSION, 3];
    body.extend_from_slice(&1u64.to_le_bytes());
    body.extend_from_slice(&2u32.to_le_bytes());
    body.extend_from_slice(&[0xFF, 0xFE]);
    assert_eq!(
        wire::decode_reply(&body),
        Err(WireError::BadPayload("non-utf8 message"))
    );
}

#[test]
fn mid_frame_eof_is_distinguished_from_clean_eof() {
    // Length word promises 32 bytes, stream carries 5: UnexpectedEof.
    let mut stream = (32u32).to_le_bytes().to_vec();
    stream.extend_from_slice(&[1, 2, 3, 4, 5]);
    let err = wire::read_frame(&mut std::io::Cursor::new(stream))
        .expect_err("torn frame must be an error");
    assert_eq!(err.kind(), std::io::ErrorKind::UnexpectedEof);

    // A torn length word itself is also UnexpectedEof, not a clean end.
    let err = wire::read_frame(&mut std::io::Cursor::new(vec![9u8, 0]))
        .expect_err("torn length word must be an error");
    assert_eq!(err.kind(), std::io::ErrorKind::UnexpectedEof);

    // Empty stream: clean EOF.
    assert!(wire::read_frame(&mut std::io::Cursor::new(Vec::new())).unwrap().is_none());
}

#[test]
fn random_junk_never_panics_the_decoders() {
    forall(
        "random junk never panics",
        0x11B3,
        default_cases().max(256),
        |rng| {
            let n = gen::index(rng, 0, 128);
            (0..n).map(|_| rng.below(256) as u8).collect::<Vec<u8>>()
        },
        |bytes| {
            // The property is "no panic"; the results themselves are
            // unconstrained (a junk body may accidentally parse).
            let _ = wire::decode_request(bytes);
            let _ = wire::decode_reply(bytes);
            let _ = wire::read_frame(&mut std::io::Cursor::new(bytes.clone()));
            true
        },
    );
}

#[test]
fn read_frame_handles_dribbling_reads() {
    // A reader that yields one byte at a time must still assemble the
    // frame (the length-word loop cannot assume a single read).
    struct OneByte<R: Read>(R);
    impl<R: Read> Read for OneByte<R> {
        fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
            if buf.is_empty() {
                return Ok(0);
            }
            self.0.read(&mut buf[..1])
        }
    }
    let id = 42u64;
    let req = Request::Step { session: 7, token: -3 };
    let frame = wire::encode_request(id, &req);
    let mut r = OneByte(std::io::Cursor::new(frame));
    let body = wire::read_frame(&mut r).expect("read ok").expect("frame present");
    let (rid, back) = wire::decode_request(&body).expect("decodes");
    assert_eq!((rid, back), (id, req));
    assert!(wire::read_frame(&mut r).expect("clean eof").is_none());
}
