//! End-to-end, multi-threaded `ConvService` tests over the native backend:
//! concurrent submits across length buckets, batch occupancy under load,
//! mid-stream filter swaps, clean shutdown draining, and statistics
//! consistency.

use std::sync::atomic::Ordering;
use std::time::Duration;

use flashfftconv::coordinator::router::ConvKind;
use flashfftconv::coordinator::service::{ConvRequest, ConvService};
use flashfftconv::coordinator::BatchPolicy;
use flashfftconv::runtime::{BackendConfig, Runtime};
use flashfftconv::util::Rng;

const HEADS: usize = 16;

fn start(batch_size: usize, wait_ms: u64) -> ConvService {
    ConvService::start(
        BackendConfig::Native,
        "monarch",
        BatchPolicy { batch_size, max_wait: Duration::from_millis(wait_ms) },
    )
    .expect("service starts")
}

#[test]
fn concurrent_submits_across_buckets_all_answered() {
    let service = start(2, 5);
    let clients = 4usize;
    let per_client = 6usize;
    std::thread::scope(|s| {
        for c in 0..clients {
            let service = &service;
            s.spawn(move || {
                let mut rng = Rng::new(1000 + c as u64);
                let mut pending = vec![];
                for i in 0..per_client {
                    // Mix exact-bucket and padded lengths across buckets.
                    let len = match (i + c) % 3 {
                        0 => 256,
                        1 => 200,  // pads into 256
                        _ => 1000, // pads into 1024
                    };
                    let u = rng.normal_vec(HEADS * len);
                    pending.push((
                        len,
                        service.submit(ConvRequest {
                            kind: ConvKind::Forward,
                            len,
                            streams: vec![u], chunk_tx: None
                        }),
                    ));
                }
                for (len, rx) in pending {
                    let row = rx.recv().expect("service alive").expect("conv ok").data;
                    assert_eq!(row.len(), HEADS * len);
                    assert!(row.iter().all(|v| v.is_finite()));
                }
            });
        }
    });
    let stats = service.stats();
    let total = (clients * per_client) as u64;
    assert_eq!(stats.requests.load(Ordering::Relaxed), total);
    assert_eq!(stats.rows_executed.load(Ordering::Relaxed), total);
    assert_eq!(stats.errors.load(Ordering::Relaxed), 0);
}

#[test]
fn batches_fill_beyond_one_row_under_load() {
    // Submit a burst before consuming any reply: with batch capacity 2 and
    // a wait window, at least some batches must pack more than one row.
    let service = start(2, 20);
    let mut rng = Rng::new(7);
    let n = 256usize;
    let rows = 12usize;
    let pending: Vec<_> = (0..rows)
        .map(|_| {
            let u = rng.normal_vec(HEADS * n);
            service.submit(ConvRequest { kind: ConvKind::Forward, len: n, streams: vec![u], chunk_tx: None })
        })
        .collect();
    for rx in pending {
        rx.recv().expect("service alive").expect("conv ok");
    }
    let stats = service.stats();
    assert_eq!(stats.rows_executed.load(Ordering::Relaxed), rows as u64);
    let batches = stats.batches.load(Ordering::Relaxed);
    assert!(
        batches < rows as u64,
        "expected some batches to pack >1 row: {batches} batches for {rows} rows"
    );
    assert!(stats.mean_occupancy() > 1.0, "occupancy {}", stats.mean_occupancy());
}

#[test]
fn set_filter_mid_stream_changes_outputs() {
    let service = start(2, 1);
    let (n, h) = (256usize, HEADS);
    let mut rng = Rng::new(42);
    let u: Vec<f32> = rng.normal_vec(h * n);
    let k1: Vec<f32> = rng.normal_vec(h * n);
    let k2: Vec<f32> = rng.normal_vec(h * n);

    service.set_filter(ConvKind::Forward, n, k1.clone()).unwrap();
    let y1 = service
        .call(ConvRequest { kind: ConvKind::Forward, len: n, streams: vec![u.clone()], chunk_tx: None })
        .unwrap();
    service.set_filter(ConvKind::Forward, n, k2.clone()).unwrap();
    let y2 = service
        .call(ConvRequest { kind: ConvKind::Forward, len: n, streams: vec![u.clone()], chunk_tx: None })
        .unwrap();

    let max_delta = y1
        .iter()
        .zip(&y2)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_delta > 1e-3, "filter swap must change outputs (delta {max_delta})");

    // Both answers match the oracle under their respective filters.
    for (y, k) in [(&y1, &k1), (&y2, &k2)] {
        for hi in 0..h {
            let urow: Vec<f64> = u[hi * n..(hi + 1) * n].iter().map(|&x| x as f64).collect();
            let krow: Vec<f64> = k[hi * n..(hi + 1) * n].iter().map(|&x| x as f64).collect();
            let want = flashfftconv::fft::fft_conv(&urow, &krow);
            for (g, w) in y[hi * n..(hi + 1) * n].iter().zip(&want) {
                assert!((*g as f64 - w).abs() < 1e-4, "head {hi}");
            }
        }
    }
}

#[test]
fn set_filter_validates_bucket_and_length() {
    let service = start(2, 1);
    // No such exact bucket.
    assert!(service.set_filter(ConvKind::Forward, 300, vec![0.0; HEADS * 300]).is_err());
    // Wrong length for a real bucket.
    assert!(service.set_filter(ConvKind::Forward, 256, vec![0.0; 7]).is_err());
    // Correct installs fine.
    assert!(service.set_filter(ConvKind::Forward, 256, vec![0.0; HEADS * 256]).is_ok());
}

#[test]
fn shutdown_drains_pending_requests() {
    // Large wait window so requests are still queued when we drop the
    // service; the drop path must force-flush and answer every receiver.
    let service = start(2, 5_000);
    let mut rng = Rng::new(9);
    let n = 256usize;
    let pending: Vec<_> = (0..5)
        .map(|_| {
            let u = rng.normal_vec(HEADS * n);
            service.submit(ConvRequest { kind: ConvKind::Forward, len: n, streams: vec![u], chunk_tx: None })
        })
        .collect();
    drop(service);
    for rx in pending {
        let reply = rx.recv().expect("drain must answer every pending request");
        assert!(reply.is_ok(), "drained replies should be successful: {reply:?}");
    }
}

#[test]
fn latency_stats_are_consistent() {
    let service = start(2, 2);
    let mut rng = Rng::new(11);
    let n = 256usize;
    for _ in 0..6 {
        let u = rng.normal_vec(HEADS * n);
        service
            .call(ConvRequest { kind: ConvKind::Forward, len: n, streams: vec![u], chunk_tx: None })
            .unwrap();
    }
    let s = service.stats();
    let reqs = s.requests.load(Ordering::Relaxed);
    assert_eq!(reqs, 6);
    assert_eq!(s.rows_executed.load(Ordering::Relaxed), 6);
    assert_eq!(s.errors.load(Ordering::Relaxed), 0);
    let sum = s.latency_ns_sum.load(Ordering::Relaxed);
    let max = s.latency_ns_max.load(Ordering::Relaxed);
    assert!(sum > 0 && max > 0);
    // max <= sum, and the mean derived from the counters matches the
    // accessor's arithmetic.
    assert!(max <= sum);
    let mean_ms = s.mean_latency_ms();
    assert!((mean_ms - sum as f64 / reqs as f64 / 1e6).abs() < 1e-9);
    assert!(max as f64 / 1e6 >= mean_ms);
}

#[test]
fn gated_requests_serve_three_streams() {
    let service = start(2, 1);
    let (n, h) = (256usize, HEADS);
    let mut rng = Rng::new(13);
    let k: Vec<f32> = rng.normal_vec(h * n);
    service.set_filter(ConvKind::Gated, n, k.clone()).unwrap();
    let u: Vec<f32> = rng.normal_vec(h * n);
    let v: Vec<f32> = rng.normal_vec(h * n);
    let w: Vec<f32> = rng.normal_vec(h * n);
    let y = service
        .call(ConvRequest {
            kind: ConvKind::Gated,
            len: n,
            streams: vec![u.clone(), v.clone(), w.clone()], chunk_tx: None
        })
        .unwrap();
    assert_eq!(y.len(), h * n);
    for hi in 0..h {
        let urow: Vec<f64> = (0..n)
            .map(|t| u[hi * n + t] as f64 * w[hi * n + t] as f64)
            .collect();
        let krow: Vec<f64> = k[hi * n..(hi + 1) * n].iter().map(|&x| x as f64).collect();
        let conv = flashfftconv::fft::fft_conv(&urow, &krow);
        for t in 0..n {
            let want = v[hi * n + t] as f64 * conv[t];
            let got = y[hi * n + t] as f64;
            assert!((got - want).abs() < 1e-4, "head {hi} t {t}: {got} vs {want}");
        }
    }
}

#[test]
fn two_services_share_nothing() {
    // Two services over independent runtimes: filters installed on one
    // must not leak into the other.
    let a = start(2, 1);
    let b = start(2, 1);
    let n = 256usize;
    let mut rng = Rng::new(17);
    let ka: Vec<f32> = rng.normal_vec(HEADS * n);
    a.set_filter(ConvKind::Forward, n, ka).unwrap();
    // b still uses its deterministic default filter; same input gives
    // different outputs across the two services.
    let u: Vec<f32> = rng.normal_vec(HEADS * n);
    let ya = a
        .call(ConvRequest { kind: ConvKind::Forward, len: n, streams: vec![u.clone()], chunk_tx: None })
        .unwrap();
    let yb = b
        .call(ConvRequest { kind: ConvKind::Forward, len: n, streams: vec![u], chunk_tx: None })
        .unwrap();
    let delta = ya.iter().zip(&yb).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max);
    assert!(delta > 1e-3, "independent services must not share filters");
    // Sanity: the native runtime itself is cheap to stand up repeatedly.
    let r = Runtime::native().unwrap();
    assert_eq!(r.backend_name(), "native");
}
