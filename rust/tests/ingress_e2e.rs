//! Ingress end-to-end: real TCP clients over loopback against the
//! sharded fleet, behind the wire-framed front.
//!
//! The acceptance soak drives >= 8 concurrent pipelined clients into a
//! 4-shard conv fleet while a concurrent wire client races two-phase
//! filter swaps, and checks: bitwise parity with a direct in-process
//! single-worker `ConvService`, zero lost or duplicated replies (FIFO
//! ids), and per-connection epoch monotonicity (no client ever observes
//! epoch `e` then `e - 1`). Further tests cover graceful shard drain
//! under live wire traffic (zero non-retryable client failures), the
//! connection-pool load shed, malformed-frame handling on a live socket,
//! session reaping for vanished clients, the per-shard inflight gauge
//! reconciliation, and writer teardown on a peer killed mid-reply
//! (half-written frames must release the pool slot and reap sessions).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use flashfftconv::coordinator::fleet::DrainOutcome;
use flashfftconv::coordinator::router::ConvKind;
use flashfftconv::coordinator::service::{ConvRequest, ConvService};
use flashfftconv::coordinator::BatchPolicy;
use flashfftconv::ingress::client::IngressClient;
use flashfftconv::ingress::wire::{self, Reply, Request};
use flashfftconv::ingress::{IngressConfig, IngressServer};
use flashfftconv::runtime::BackendConfig;
use flashfftconv::server::ModelServer;
use flashfftconv::util::Rng;

const HEADS: usize = 16;

fn sharded(shards: usize, max_inflight: usize) -> Arc<ConvService> {
    Arc::new(
        ConvService::start_sharded(
            BackendConfig::NativeRowThreads(1),
            "monarch",
            BatchPolicy { batch_size: 2, max_wait: Duration::from_millis(2) },
            shards,
            max_inflight,
        )
        .expect("sharded service starts"),
    )
}

fn forward(len: usize, u: Vec<f32>) -> ConvRequest {
    ConvRequest { kind: ConvKind::Forward, len, streams: vec![u], chunk_tx: None }
}

/// Same request mix as the fleet soak: mostly 256 (some padded), every
/// 4th request in the 1024 bucket.
fn soak_len(c: usize, i: usize) -> usize {
    match (c + i) % 4 {
        0 => 1024,
        1 => 200, // pads into 256
        _ => 256,
    }
}

/// Poll `cond` until it holds or `secs` elapse.
fn eventually(secs: u64, mut cond: impl FnMut() -> bool) -> bool {
    let deadline = Instant::now() + Duration::from_secs(secs);
    loop {
        if cond() {
            return true;
        }
        if Instant::now() >= deadline {
            return false;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
}

#[test]
fn soak_wire_clients_parity_epoch_monotonic_under_concurrent_swaps() {
    const CLIENTS: usize = 8;
    const PER_CLIENT: usize = 32;
    const WINDOW: usize = 4;

    let service = sharded(4, 64);
    let single = ConvService::start(
        BackendConfig::Native,
        "monarch",
        BatchPolicy { batch_size: 2, max_wait: Duration::from_millis(1) },
    )
    .expect("reference service starts");

    // Identical Forward filter banks on both sides; the concurrent swaps
    // below hit the *Causal* 512 bucket, which the soak never routes to,
    // so bitwise parity must hold throughout.
    let mut rng = Rng::new(4242);
    for bucket in [256usize, 1024] {
        let k = rng.normal_vec(HEADS * bucket);
        service
            .set_filter(ConvKind::Forward, bucket, k.clone())
            .expect("fleet filter installs");
        single.set_filter(ConvKind::Forward, bucket, k).expect("single filter installs");
    }

    let ingress = IngressServer::bind(
        "127.0.0.1:0",
        Some(Arc::clone(&service)),
        None,
        IngressConfig::default(),
    )
    .expect("ingress binds");
    let addr = ingress.local_addr();

    let stop = AtomicBool::new(false);
    let swaps = AtomicU64::new(0);
    let retried = AtomicU64::new(0);
    std::thread::scope(|s| {
        // Two-phase filter swaps racing the soak over their own wire
        // connection.
        {
            let (stop, swaps) = (&stop, &swaps);
            s.spawn(move || {
                let mut client = IngressClient::connect(addr).expect("swap client connects");
                let mut rng = Rng::new(0x5A4B);
                while !stop.load(Ordering::Relaxed) {
                    let taps = rng.normal_vec(HEADS * 512);
                    let req = Request::InstallFilter { kind: 2, bucket: 512, taps };
                    match client
                        .call_retry(&req, 4096, Duration::from_micros(200))
                        .expect("swap round trip")
                    {
                        Reply::Ok { .. } => {
                            swaps.fetch_add(1, Ordering::Relaxed);
                        }
                        other => panic!("filter swap failed: {other:?}"),
                    }
                    std::thread::sleep(Duration::from_millis(1));
                }
                client.finish();
            });
        }

        let mut handles = Vec::new();
        for c in 0..CLIENTS {
            let (single, retried) = (&single, &retried);
            handles.push(s.spawn(move || {
                let mut rng = Rng::new(9_000 + c as u64);
                let mut client = IngressClient::connect(addr).expect("client connects");
                let mut to_send: VecDeque<(usize, Vec<f32>)> = (0..PER_CLIENT)
                    .map(|i| {
                        let len = soak_len(c, i);
                        (len, rng.normal_vec(HEADS * len))
                    })
                    .collect();
                let mut queue: VecDeque<(u64, usize, Vec<f32>)> = VecDeque::new();
                let mut done: Vec<(usize, Vec<f32>, Vec<f32>)> = Vec::new();
                let mut watermark = 0u64;
                while done.len() < PER_CLIENT {
                    // Keep a pipelining window of requests on the wire.
                    while queue.len() < WINDOW {
                        match to_send.pop_front() {
                            Some((len, u)) => {
                                let req = Request::Conv {
                                    kind: 0,
                                    len: len as u32,
                                    streams: vec![u.clone()],
                                };
                                let id = client.send(&req).expect("send");
                                queue.push_back((id, len, u));
                            }
                            None => break,
                        }
                    }
                    let (id, len, u) = queue.pop_front().expect("a request is outstanding");
                    let (rid, reply) = client.recv().expect("reply arrives");
                    // FIFO ids: exactly one reply per request, in order —
                    // nothing lost, nothing duplicated.
                    assert_eq!(rid, id, "client {c}: reply out of order");
                    match reply {
                        Reply::Ok { epoch, session, data } => {
                            assert!(session.is_none());
                            assert!(
                                epoch >= watermark,
                                "client {c}: observed epoch {epoch} after {watermark}"
                            );
                            watermark = epoch;
                            assert_eq!(data.len(), HEADS * len);
                            done.push((len, u, data));
                        }
                        r if r.retryable() => {
                            // Load shed under the swap races: resubmit
                            // with a fresh id at the back of the window.
                            retried.fetch_add(1, Ordering::Relaxed);
                            std::thread::sleep(Duration::from_micros(200));
                            to_send.push_back((len, u));
                        }
                        other => panic!("client {c}: non-retryable reply: {other:?}"),
                    }
                }
                client.finish();
                // Bitwise parity vs the direct in-process service.
                for (len, u, y) in done {
                    let want = single.call(forward(len, u)).expect("single-worker conv ok");
                    assert_eq!(y, want, "client {c}: wire output diverged from in-process");
                }
                watermark
            }));
        }
        for h in handles {
            h.join().expect("client thread");
        }
        stop.store(true, Ordering::Relaxed);
    });

    let n_swaps = swaps.load(Ordering::Relaxed);
    assert!(n_swaps >= 1, "at least one concurrent swap must have landed");

    // Epoch accounting: 2 initial installs + every landed swap.
    let stats = service.fleet().stats();
    assert_eq!(stats.filter_epoch, 2 + n_swaps, "control epochs must be dense");
    assert_eq!(stats.errors, 0);
    assert_eq!(stats.shard_deaths, 0);
    assert_eq!(stats.inflight, 0, "quiescent fleet holds no slots");
    for sh in &stats.shards {
        assert_eq!(
            sh.inflight_requests, 0,
            "shard {} gauge must reconcile to zero at rest",
            sh.shard
        );
    }

    // Every request frame got exactly one reply frame (the writer's
    // counter trails the client's last read by a flush, so poll).
    let ist = ingress.stats();
    assert!(
        eventually(5, || {
            ist.replies_out.load(Ordering::Relaxed) == ist.frames_in.load(Ordering::Relaxed)
        }),
        "replies_out must converge to frames_in: {} vs {}",
        ist.replies_out.load(Ordering::Relaxed),
        ist.frames_in.load(Ordering::Relaxed)
    );
    assert_eq!(ist.bad_frames.load(Ordering::Relaxed), 0);
}

#[test]
fn drain_during_wire_soak_never_fails_a_client_request() {
    const CLIENTS: usize = 8;
    const PER_CLIENT: usize = 24;

    let service = sharded(4, 64);
    let ingress = IngressServer::bind(
        "127.0.0.1:0",
        Some(Arc::clone(&service)),
        None,
        IngressConfig::default(),
    )
    .expect("ingress binds");
    let addr = ingress.local_addr();

    std::thread::scope(|s| {
        for c in 0..CLIENTS {
            s.spawn(move || {
                let mut rng = Rng::new(3_000 + c as u64);
                let mut client = IngressClient::connect(addr).expect("client connects");
                for i in 0..PER_CLIENT {
                    let len = soak_len(c, i);
                    let u = rng.normal_vec(HEADS * len);
                    let req =
                        Request::Conv { kind: 0, len: len as u32, streams: vec![u] };
                    // A graceful drain must surface as — at worst — a
                    // retryable Busy, never a failure or a dead shard.
                    loop {
                        match client.call(&req).expect("wire round trip") {
                            Reply::Ok { data, .. } => {
                                assert_eq!(data.len(), HEADS * len);
                                break;
                            }
                            Reply::Busy => std::thread::sleep(Duration::from_micros(200)),
                            other => panic!(
                                "client {c}: request failed during drain: {other:?}"
                            ),
                        }
                    }
                }
                client.finish();
            });
        }

        // Mid-soak: rolling-restart one shard, then scale another down
        // and back up, all while traffic flows.
        std::thread::sleep(Duration::from_millis(30));
        service
            .fleet()
            .drain(1, DrainOutcome::Respawn, Duration::from_secs(60))
            .expect("drain-respawn while serving");
        service
            .fleet()
            .drain(2, DrainOutcome::Retire, Duration::from_secs(60))
            .expect("drain-retire while serving");
        std::thread::sleep(Duration::from_millis(20));
        service.fleet().revive(2, Duration::from_secs(60)).expect("revive while serving");
    });

    let stats = service.fleet().stats();
    assert!(stats.drains >= 2, "both drains must be recorded (got {})", stats.drains);
    assert_eq!(stats.shard_deaths, 0, "graceful drain must not strand replies");
    assert_eq!(stats.errors, 0);
    assert_eq!(stats.inflight, 0);
    assert!(
        stats.shards.iter().all(|sh| sh.alive && !sh.draining),
        "every shard must be back in rotation after the drain cycle"
    );
}

#[test]
fn over_cap_connections_are_shed_with_busy() {
    let service = Arc::new(
        ConvService::start(
            BackendConfig::Native,
            "monarch",
            BatchPolicy { batch_size: 2, max_wait: Duration::from_millis(1) },
        )
        .expect("service starts"),
    );
    let ingress = IngressServer::bind(
        "127.0.0.1:0",
        Some(Arc::clone(&service)),
        None,
        IngressConfig { max_connections: 1, ..IngressConfig::default() },
    )
    .expect("ingress binds");
    let addr = ingress.local_addr();

    // First connection occupies the only pool slot (prove it works).
    let mut a = IngressClient::connect(addr).expect("first client connects");
    let mut rng = Rng::new(5);
    let u = rng.normal_vec(HEADS * 256);
    match a
        .call_retry(&Request::Conv { kind: 0, len: 256, streams: vec![u] }, 64, Duration::from_millis(1))
        .expect("first client round trip")
    {
        Reply::Ok { data, .. } => assert_eq!(data.len(), HEADS * 256),
        other => panic!("pooled connection must serve: {other:?}"),
    }

    // Wait until the pool actually registered the first connection, then
    // the second one must be shed with a retryable busy frame (id 0).
    assert!(
        eventually(5, || {
            let mut b = match IngressClient::connect(addr) {
                Ok(b) => b,
                Err(_) => return false,
            };
            matches!(b.recv(), Ok((0, Reply::Busy)))
        }),
        "over-cap connection must receive the busy shed frame"
    );
    assert!(ingress.stats().shed.load(Ordering::Relaxed) >= 1);

    // Freeing the slot re-opens the pool.
    a.finish();
    drop(a);
    assert!(
        eventually(10, || {
            let mut c = match IngressClient::connect(addr) {
                Ok(c) => c,
                Err(_) => return false,
            };
            let u: Vec<f32> = vec![0.0; HEADS * 256];
            let req = Request::Conv { kind: 0, len: 256, streams: vec![u] };
            matches!(
                c.call_retry(&req, 64, Duration::from_millis(1)),
                Ok(Reply::Ok { .. })
            )
        }),
        "pool slot must free up after the first client disconnects"
    );
}

#[test]
fn malformed_frames_get_bad_request_and_the_connection_survives() {
    use std::io::Write;

    let service = Arc::new(
        ConvService::start(
            BackendConfig::Native,
            "monarch",
            BatchPolicy { batch_size: 2, max_wait: Duration::from_millis(1) },
        )
        .expect("service starts"),
    );
    let ingress = IngressServer::bind(
        "127.0.0.1:0",
        Some(Arc::clone(&service)),
        None,
        IngressConfig::default(),
    )
    .expect("ingress binds");

    let mut stream =
        std::net::TcpStream::connect(ingress.local_addr()).expect("raw connect");

    // Unknown opcode 99, request id 77: the reply must be bad_request and
    // must echo the id so the client can correlate it.
    let mut body = vec![wire::WIRE_VERSION, 99];
    body.extend_from_slice(&77u64.to_le_bytes());
    let mut frame = (body.len() as u32).to_le_bytes().to_vec();
    frame.extend_from_slice(&body);
    stream.write_all(&frame).expect("write malformed frame");
    let reply = wire::read_frame(&mut stream).expect("read ok").expect("reply present");
    let (rid, reply) = wire::decode_reply(&reply).expect("reply decodes");
    assert_eq!(rid, 77);
    assert!(matches!(reply, Reply::BadRequest { .. }), "got {reply:?}");

    // Wrong version byte: rejected, message names the version.
    let mut body = vec![wire::WIRE_VERSION + 1, 1];
    body.extend_from_slice(&78u64.to_le_bytes());
    let mut frame = (body.len() as u32).to_le_bytes().to_vec();
    frame.extend_from_slice(&body);
    stream.write_all(&frame).expect("write wrong-version frame");
    let reply = wire::read_frame(&mut stream).expect("read ok").expect("reply present");
    match wire::decode_reply(&reply).expect("reply decodes") {
        (78, Reply::BadRequest { msg }) => {
            assert!(msg.contains("version"), "message must name the version: {msg}")
        }
        other => panic!("expected bad_request for wrong version, got {other:?}"),
    }

    // The same connection still serves valid requests afterwards.
    let mut rng = Rng::new(6);
    let u = rng.normal_vec(HEADS * 256);
    let frame =
        wire::encode_request(79, &Request::Conv { kind: 0, len: 256, streams: vec![u] });
    stream.write_all(&frame).expect("write valid frame");
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let reply = wire::read_frame(&mut stream).expect("read ok").expect("reply present");
        match wire::decode_reply(&reply).expect("reply decodes") {
            (79, Reply::Ok { data, .. }) => {
                assert_eq!(data.len(), HEADS * 256);
                break;
            }
            (79, Reply::Busy) => {
                assert!(Instant::now() < deadline, "service stayed busy");
                std::thread::sleep(Duration::from_millis(1));
                let u = rng.normal_vec(HEADS * 256);
                let f = wire::encode_request(
                    79,
                    &Request::Conv { kind: 0, len: 256, streams: vec![u] },
                );
                stream.write_all(&f).expect("rewrite valid frame");
            }
            other => panic!("poisoned connection after bad frames: {other:?}"),
        }
    }
    assert!(ingress.stats().bad_frames.load(Ordering::Relaxed) >= 2);
}

#[test]
fn vanished_connection_reaps_its_open_sessions() {
    let server = Arc::new(
        ModelServer::start(
            BackendConfig::Native,
            "lm_fwd_logits",
            BatchPolicy { batch_size: 2, max_wait: Duration::from_millis(2) },
        )
        .expect("model server starts"),
    );
    let ingress = IngressServer::bind(
        "127.0.0.1:0",
        None,
        Some(Arc::clone(&server)),
        IngressConfig::default(),
    )
    .expect("ingress binds");
    let addr = ingress.local_addr();

    let prompt = vec![1i32; server.seq_len];
    let mut client = IngressClient::connect(addr).expect("client connects");

    // Full-context inference over the wire works.
    match client
        .call_retry(&Request::LmLogits { tokens: prompt.clone() }, 64, Duration::from_millis(1))
        .expect("lm_logits round trip")
    {
        Reply::Ok { data, .. } => assert_eq!(data.len(), server.vocab),
        other => panic!("lm_logits failed: {other:?}"),
    }

    // Open a decode session, step it once — then vanish without closing.
    let sid = match client
        .call_retry(&Request::OpenSession { prompt }, 64, Duration::from_millis(1))
        .expect("open round trip")
    {
        Reply::Ok { session: Some(sid), data, .. } => {
            assert_eq!(data.len(), server.vocab);
            sid
        }
        other => panic!("open_session failed: {other:?}"),
    };
    match client.call(&Request::Step { session: sid, token: 1 }).expect("step round trip") {
        Reply::Ok { data, .. } => assert_eq!(data.len(), server.vocab),
        other => panic!("step failed: {other:?}"),
    }
    drop(client); // connection dies with the session still open

    // The connection teardown must best-effort close the session so the
    // engine's capped session map gets its slot back.
    let ist = ingress.stats();
    assert!(
        eventually(30, || ist.sessions_reaped.load(Ordering::Relaxed) >= 1),
        "teardown must reap the abandoned session"
    );

    // A different connection never shares session visibility: the id is
    // rejected before it can touch another client's state.
    let mut other = IngressClient::connect(addr).expect("second client connects");
    match other.call(&Request::Step { session: sid, token: 2 }).expect("round trip") {
        Reply::SessionLost => {}
        other => panic!("foreign session id must read as lost, got {other:?}"),
    }
    other.finish();
}

#[test]
fn peer_killed_mid_reply_releases_the_slot_and_reaps_sessions() {
    use std::io::Write;
    use std::net::Shutdown;

    // Conv + model behind one front: large conv replies to wedge the
    // writer mid-frame, a decode session to prove teardown still reaps.
    let service = sharded(1, 32);
    let server = Arc::new(
        ModelServer::start(
            BackendConfig::Native,
            "lm_fwd_logits",
            BatchPolicy { batch_size: 2, max_wait: Duration::from_millis(2) },
        )
        .expect("model server starts"),
    );
    let ingress = IngressServer::bind(
        "127.0.0.1:0",
        Some(Arc::clone(&service)),
        Some(Arc::clone(&server)),
        IngressConfig {
            // Bound the writer even if the kernel buffers the kill.
            write_timeout: Some(Duration::from_secs(2)),
            ..IngressConfig::default()
        },
    )
    .expect("ingress binds");

    let mut stream = std::net::TcpStream::connect(ingress.local_addr()).expect("raw connect");

    // Open a session (and read its reply, so it is definitely open).
    let prompt = vec![1i32; server.seq_len];
    stream
        .write_all(&wire::encode_request(1, &Request::OpenSession { prompt }))
        .expect("open frame");
    let body = wire::read_frame(&mut stream).expect("read ok").expect("reply present");
    match wire::decode_reply(&body).expect("decodes") {
        (1, Reply::Ok { session: Some(_), .. }) => {}
        other => panic!("open_session failed: {other:?}"),
    }

    // Pipeline large conv requests (each reply is HEADS * 4096 f32s ≈
    // 256 KiB — far beyond a loopback socket buffer once we stop
    // reading), then kill the connection without reading a byte: the
    // writer is mid-frame or about to be.
    let mut rng = Rng::new(77);
    for i in 0..6u64 {
        let u = rng.normal_vec(HEADS * 4096);
        let req = Request::Conv { kind: 0, len: 4096, streams: vec![u] };
        stream.write_all(&wire::encode_request(10 + i, &req)).expect("conv frame");
    }
    std::thread::sleep(Duration::from_millis(50));
    let _ = stream.shutdown(Shutdown::Both);
    drop(stream);

    // The half-written reply must not wedge anything: the writer exits,
    // the pool slot frees, the abandoned session is reaped, and every
    // fleet slot settles.
    let ist = ingress.stats();
    assert!(
        eventually(30, || ingress.open_connections() == 0),
        "killed connection must leave the pool"
    );
    assert!(
        eventually(30, || ist.sessions_reaped.load(Ordering::Relaxed) >= 1),
        "mid-write teardown must still reap sessions"
    );
    assert!(
        eventually(30, || service.fleet().stats().inflight == 0),
        "fleet slots must settle after the peer dies"
    );

    // The front still serves new connections afterwards.
    let mut client = IngressClient::connect(ingress.local_addr()).expect("fresh client");
    let u = rng.normal_vec(HEADS * 256);
    match client
        .call_retry(&Request::Conv { kind: 0, len: 256, streams: vec![u] }, 64, Duration::from_millis(1))
        .expect("round trip")
    {
        Reply::Ok { data, .. } => assert_eq!(data.len(), HEADS * 256),
        other => panic!("front wedged after mid-write kill: {other:?}"),
    }
    client.finish();
}

#[test]
fn inflight_gauges_track_and_reconcile() {
    // One shard, long batch window: admitted requests deterministically
    // stay in flight until the deadline flush, so the per-shard gauge is
    // exact mid-flight and must return to zero at rest.
    let service = Arc::new(
        ConvService::start_sharded(
            BackendConfig::NativeRowThreads(1),
            "monarch",
            BatchPolicy { batch_size: 2, max_wait: Duration::from_millis(250) },
            1,
            8,
        )
        .expect("service starts"),
    );
    let mut rng = Rng::new(31);
    let pending: Vec<_> = [256usize, 1024, 4096]
        .iter()
        .map(|&len| {
            let u = rng.normal_vec(HEADS * len);
            service.fleet().submit(forward(len, u)).expect("admitted")
        })
        .collect();

    let stats = service.fleet().stats();
    assert_eq!(stats.inflight, 3);
    assert_eq!(stats.shards[0].inflight_requests, 3, "per-shard gauge tracks dispatch");

    for rx in pending {
        rx.recv().expect("fleet alive").expect("conv ok");
    }
    let stats = service.fleet().stats();
    assert_eq!(stats.inflight, 0);
    assert_eq!(stats.shards[0].inflight_requests, 0, "gauge reconciles to zero");
    assert_eq!(stats.completed, 3);
    assert_eq!(stats.submitted, 3);
    assert_eq!(stats.requests, 3, "dispatched == admitted == completed");
}

#[test]
fn live_streamed_long_conv_matches_in_process_bitwise() {
    // A genome-style bucket small enough for CI: 50k points, 129 taps,
    // and a workspace budget that forces the chunked overlap-add path.
    let n = 50_000usize;
    let lk = 129usize;
    let budget = flashfftconv::fft::chunked::chunk_scratch_bytes(2 * 4096, 1);
    let service = Arc::new(
        ConvService::start_sharded(
            BackendConfig::NativeLongConv { n, filter_len: lk, budget_bytes: budget },
            "monarch",
            BatchPolicy { batch_size: 1, max_wait: Duration::from_millis(1) },
            1,
            16,
        )
        .expect("long-conv service starts"),
    );
    let mut rng = Rng::new(0x10C0);
    let epoch =
        service.set_filter(ConvKind::Causal, n, rng.normal_vec(lk)).expect("filter installs");
    let u = rng.normal_vec(n);

    // In-process reference through the very same engine (materialized).
    let rx = service
        .fleet()
        .submit(ConvRequest {
            kind: ConvKind::Causal,
            len: n,
            streams: vec![u.clone()],
            chunk_tx: None,
        })
        .expect("in-process submit");
    let want = rx.recv().expect("reply slot").expect("in-process ok");
    assert_eq!(want.data.len(), n);
    assert_eq!(want.epoch, epoch);

    // The same request over TCP with live streaming forced on for every
    // conv (threshold 1) and small frames so the run is many chunks.
    let ingress = IngressServer::bind(
        "127.0.0.1:0",
        Some(service.clone()),
        None,
        IngressConfig {
            stream_conv_threshold_points: 1,
            stream_chunk_points: 1 << 13,
            ..IngressConfig::default()
        },
    )
    .expect("ingress binds");
    let mut client = IngressClient::connect(ingress.local_addr()).expect("client connects");
    let id = client
        .send(&Request::Conv { kind: 2, len: n as u32, streams: vec![u] })
        .expect("send");
    let mut got: Vec<f32> = Vec::with_capacity(n);
    let mut calls = 0usize;
    let (rid, reply) = client
        .recv_chunks(|part| {
            calls += 1;
            got.extend_from_slice(part);
            Ok(())
        })
        .expect("streamed reply");
    assert_eq!(rid, id);
    let Reply::Ok { epoch: served, data, .. } = reply else {
        panic!("expected ok, got {reply:?}");
    };
    assert!(data.is_empty(), "recv_chunks drains the payload through the callback");
    assert_eq!(served, epoch, "fin frame carries the served filter epoch");
    assert!(calls > 1, "a streamed reply must arrive as multiple live chunks ({calls})");
    assert_eq!(got.len(), n);
    for (i, (a, b)) in got.iter().zip(&want.data).enumerate() {
        assert!(
            a.to_bits() == b.to_bits(),
            "streamed/in-process bit mismatch at {i}: {a:e} vs {b:e}"
        );
    }
    assert!(ingress.stats().chunks_out.load(Ordering::Relaxed) > 1);

    // A short causal request on the same connection: the chunk channel
    // still attaches (threshold 1), but the routed 512-bucket is
    // batch-2/16-head and cannot chunk, so the reply transparently
    // degrades to the buffered path — same client code, one callback.
    let short = 256usize;
    let us = rng.normal_vec(HEADS * short);
    let sid = client
        .send(&Request::Conv { kind: 2, len: short as u32, streams: vec![us] })
        .expect("short send");
    let mut short_calls = 0usize;
    let mut short_got: Vec<f32> = Vec::new();
    let (srid, sreply) = client
        .recv_chunks(|part| {
            short_calls += 1;
            short_got.extend_from_slice(part);
            Ok(())
        })
        .expect("short reply");
    assert_eq!(srid, sid);
    assert!(matches!(sreply, Reply::Ok { .. }), "short conv ok, got {sreply:?}");
    assert_eq!(short_got.len(), HEADS * short);
    assert_eq!(short_calls, 1, "buffered fallback arrives as one callback");
}
