//! End-to-end model-zoo tests on the default native backend: ModelServer
//! generation determinism, the pathfinder train-then-eval round trip
//! (loss decreasing from init, held-out accuracy improving), parity
//! between parallel and sequential conv-engine execution, and the e2e
//! monarch/baseline pairs agreeing on shared parameters.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use flashfftconv::coordinator::BatchPolicy;
use flashfftconv::runtime::{Artifact, BackendConfig, HostTensor, Runtime};
use flashfftconv::server::{InferRequest, ModelServer};
use flashfftconv::trainer::data::{PathfinderGen, TokenGen};
use flashfftconv::trainer::run::Budget;
use flashfftconv::trainer::{TrainConfig, Trainer};
use flashfftconv::coordinator::fleet::FleetError;
use flashfftconv::server::{ModelRequest, SessionOp};
use flashfftconv::util::Rng;
use flashfftconv::zoo::sample::{greedy_extend, greedy_extend_full};

fn start_server() -> ModelServer {
    ModelServer::start(
        BackendConfig::Native,
        "lm_fwd_logits",
        BatchPolicy { batch_size: 4, max_wait: Duration::from_millis(1) },
    )
    .expect("model server starts on the native backend")
}

#[test]
fn model_server_generation_is_deterministic_on_native() {
    let s1 = start_server();
    let s2 = start_server();
    let mut gen = TokenGen::new(s1.vocab, 7);
    let prompt = gen.batch(1, s1.seq_len);

    let a = greedy_extend(&s1, &prompt, 8).unwrap();
    let b = greedy_extend(&s2, &prompt, 8).unwrap();
    assert_eq!(a, b, "two fresh servers must generate identically");
    let c = greedy_extend(&s1, &prompt, 8).unwrap();
    assert_eq!(a, c, "the same server must be deterministic across calls");

    assert_eq!(a.len(), s1.seq_len + 8);
    assert!(a[s1.seq_len..].iter().all(|&t| t >= 0 && (t as usize) < s1.vocab));

    // Error paths stay clean: wrong prompt length, wrong request length.
    assert!(greedy_extend(&s1, &prompt[..10], 1).is_err());
    assert!(s1.call(InferRequest { tokens: vec![0; 3] }).is_err());
}

#[test]
fn model_server_batches_concurrent_generation_requests() {
    let server = start_server();
    let mut gen = TokenGen::new(server.vocab, 3);
    // Submit a burst of identical full-context requests; every reply is
    // the same last-position logits vector.
    let prompt = gen.batch(1, server.seq_len);
    let pending: Vec<_> = (0..6)
        .map(|_| server.submit(InferRequest { tokens: prompt.clone() }))
        .collect();
    let mut replies = vec![];
    for rx in pending {
        replies.push(rx.recv().expect("server alive").expect("inference ok").data);
    }
    for r in &replies[1..] {
        assert_eq!(r, &replies[0], "identical requests must get identical logits");
    }
    assert_eq!(replies[0].len(), server.vocab);
}

#[test]
fn decode_session_matches_full_recompute_first_token_and_open_logits() {
    let server = start_server();
    let mut gen = TokenGen::new(server.vocab, 21);
    let prompt = gen.batch(1, server.seq_len);

    // For the very first generated token the full path's context window
    // IS the prompt, so the session chain and the sliding-window chain
    // must agree there (they are allowed to diverge later: growing
    // history vs re-truncated window).
    let a = greedy_extend(&server, &prompt, 4).unwrap();
    let b = greedy_extend_full(&server, &prompt, 4).unwrap();
    assert_eq!(a[server.seq_len], b[server.seq_len], "first generated token must agree");
    assert_eq!(a.len(), server.seq_len + 4);
    assert!(a[server.seq_len..].iter().all(|&t| t >= 0 && (t as usize) < server.vocab));

    // The open-reply logits are exactly one full forward of the prompt.
    let (session, open_logits) = server.open_session(&prompt).unwrap();
    let full = server.call(InferRequest { tokens: prompt.clone() }).unwrap();
    assert_eq!(open_logits, full, "open_session logits must equal a plain forward");
    let step = session.step(a[server.seq_len]).unwrap();
    assert_eq!(step.len(), server.vocab);
    assert!(step.iter().all(|v| v.is_finite()));
    session.close();

    // Bad prompt lengths are rejected before any shard is touched.
    assert!(server.open_session(&prompt[..server.seq_len - 1]).is_err());
}

#[test]
fn decode_step_after_close_is_session_lost() {
    let server = start_server();
    let mut gen = TokenGen::new(server.vocab, 5);
    let prompt = gen.batch(1, server.seq_len);
    let (session, _) = server.open_session(&prompt).unwrap();
    let (id, shard) = (session.id(), session.shard());
    session.step(1).unwrap();
    session.close();
    // The close is enqueued on the shard channel before this step, so
    // the worker sees them in order: the state is gone and the step must
    // come back as the typed, non-retryable SessionLost.
    let err = server
        .fleet()
        .call(ModelRequest::Session { shard, op: SessionOp::Step { id, token: 1 } })
        .unwrap_err();
    assert!(matches!(err, FleetError::SessionLost), "got {err}");
    assert!(!err.retryable(), "SessionLost must not be retryable");
}

#[test]
fn dropped_session_handle_frees_its_slot() {
    // Regression: a DecodeSession that fell out of scope without close()
    // used to strand its worker-side slot until the engine's capped
    // session map filled up. Drop now best-effort closes the session.
    let server = start_server();
    let mut gen = TokenGen::new(server.vocab, 6);
    let prompt = gen.batch(1, server.seq_len);
    let (session, _) = server.open_session(&prompt).unwrap();
    let (id, shard) = (session.id(), session.shard());
    session.step(1).unwrap();
    drop(session); // no close(): the Drop impl must reap the slot

    // The close rides the normal admission queue, so it lands
    // asynchronously: probe with a bounded retry until the worker
    // answers the typed SessionLost for the dead id.
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        match server
            .fleet()
            .call(ModelRequest::Session { shard, op: SessionOp::Step { id, token: 1 } })
        {
            Err(FleetError::SessionLost) => break,
            Ok(_) => {
                assert!(
                    Instant::now() < deadline,
                    "dropped session handle never freed its worker-side slot"
                );
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(e) if e.retryable() => std::thread::sleep(Duration::from_millis(10)),
            Err(e) => panic!("unexpected probe error: {e}"),
        }
    }
}

#[test]
fn decode_session_dies_with_its_shard_and_reopens() {
    let server = ModelServer::start_sharded(
        BackendConfig::Native,
        "lm_fwd_logits",
        BatchPolicy { batch_size: 4, max_wait: Duration::from_millis(1) },
        2,
        64,
    )
    .unwrap();
    let mut gen = TokenGen::new(server.vocab, 9);
    let prompt = gen.batch(1, server.seq_len);

    let (session, _) = server.open_session(&prompt).unwrap();
    session.step(0).unwrap();
    server.fleet().poison_shard(session.shard());

    // Steps racing the death may fail retryably (ShardDied); once the
    // supervisor has respawned the worker, its engine no longer holds the
    // state and the step must settle on the terminal SessionLost.
    let mut terminal = None;
    for _ in 0..200 {
        match session.step(0) {
            Ok(_) => panic!("session state must not survive a worker respawn"),
            Err(e) if e.retryable() => std::thread::sleep(Duration::from_millis(10)),
            Err(e) => {
                terminal = Some(e);
                break;
            }
        }
    }
    assert!(
        matches!(terminal, Some(FleetError::SessionLost)),
        "expected SessionLost after respawn, got {terminal:?}"
    );

    // The documented recovery: open a fresh session and replay.
    let (fresh, logits) = server.open_session(&prompt).unwrap();
    assert_eq!(logits.len(), server.vocab);
    fresh.step(0).unwrap();
    fresh.close();
}

fn eval_accuracy(eval: &mut Artifact, side: usize, batch: usize, seq: usize, seed: u64) -> f64 {
    let mut gen = PathfinderGen::new(side, seed);
    let (mut correct, mut total) = (0usize, 0usize);
    for _ in 0..16 {
        let (pix, labels) = gen.batch(batch);
        let outs = eval.call(&[HostTensor::f32(pix, &[batch, seq])]).unwrap();
        correct += flashfftconv::zoo::pathfinder::correct_predictions(outs[0].as_f32(), &labels);
        total += labels.len();
    }
    correct as f64 / total as f64
}

#[test]
fn pathfinder_train_then_eval_improves_over_init() {
    let runtime = Runtime::native().unwrap();
    let seed = 3u64;

    let mut eval = runtime.load("pf_eval").unwrap();
    let spec = eval.spec().clone();
    let batch = spec.meta_usize("batch").unwrap();
    let seq = spec.meta_usize("seq_len").unwrap();
    let side = (seq as f64).sqrt() as usize;
    assert_eq!(side * side, seq);
    let before = eval_accuracy(&mut eval, side, batch, seq, seed + 1000);

    let mut trainer = Trainer::new(
        &runtime,
        TrainConfig {
            artifact: "pf_train".into(),
            budget: Budget::Steps(200),
            log_every: 1000,
            seed,
            checkpoint: None,
        },
    )
    .unwrap();
    let o = trainer.run().unwrap();
    assert_eq!(o.steps, 200);
    assert!(
        o.final_loss < o.first_loss - 0.02,
        "training loss must decrease from init: {} -> {}",
        o.first_loss,
        o.final_loss
    );

    // Copy the trained parameters into the eval artifact (the
    // cmd_pathfinder workflow) and re-measure held-out accuracy.
    let names: Vec<String> = eval
        .spec()
        .inputs
        .iter()
        .filter(|i| i.spec.name.starts_with("param."))
        .map(|i| i.spec.name.clone())
        .collect();
    assert_eq!(names.len(), 4, "pathfinder has 4 parameter tensors");
    for name in &names {
        eval.set_operand(name, &trainer.artifact().state(name).unwrap()).unwrap();
    }
    let after = eval_accuracy(&mut eval, side, batch, seq, seed + 1000);
    assert!(
        after >= 0.75,
        "trained pathfinder accuracy should clear 75%, got {after:.3} (before {before:.3})"
    );
    assert!(
        after > before + 0.1,
        "accuracy must improve over init: {before:.3} -> {after:.3}"
    );
}

fn gated_conv_manifest(threads: usize) -> String {
    format!(
        "version 1\n\
         artifact cpar\n\
         hlo cpar.hlo.txt\n\
         meta group conv\n\
         meta kind conv_gated\n\
         meta variant monarch\n\
         meta seq_len 256\n\
         meta batch 2\n\
         meta heads 8\n\
         meta order 2\n\
         meta conv_threads {threads}\n\
         input u f32 2,8,256 runtime\n\
         input v f32 2,8,256 runtime\n\
         input w f32 2,8,256 runtime\n\
         input k f32 8,256 runtime\n\
         output y f32 2,8,256\n\
         end\n"
    )
}

#[test]
fn parallel_and_sequential_conv_engines_agree_bitwise() {
    let seq_rt = Runtime::native_from(&gated_conv_manifest(1), BTreeMap::new()).unwrap();
    let par_rt = Runtime::native_from(&gated_conv_manifest(4), BTreeMap::new()).unwrap();
    let (b, h, n) = (2usize, 8usize, 256usize);
    let mut rng = Rng::new(123);
    let inputs = vec![
        HostTensor::f32(rng.normal_vec(b * h * n), &[b, h, n]),
        HostTensor::f32(rng.normal_vec(b * h * n), &[b, h, n]),
        HostTensor::f32(rng.normal_vec(b * h * n), &[b, h, n]),
        HostTensor::f32(rng.normal_vec(h * n), &[h, n]),
    ];
    let ys = seq_rt.load("cpar").unwrap().call(&inputs).unwrap();
    let yp = par_rt.load("cpar").unwrap().call(&inputs).unwrap();
    assert_eq!(
        ys[0].as_f32(),
        yp[0].as_f32(),
        "row fan-out must not change results (bitwise)"
    );
}

#[test]
fn e2e_zoo_variants_agree_on_shared_params() {
    // The Table 5 monarch/baseline pair of one model shares its
    // parameters, so the two long-conv implementations must produce the
    // same logits — the model-level cross-implementation check.
    let runtime = Runtime::native().unwrap();
    let mut mon = runtime.load("e2e_m2bert_monarch").unwrap();
    let mut base = runtime.load("e2e_m2bert_baseline").unwrap();
    let spec = mon.spec().clone();
    let batch = spec.meta_usize("batch").unwrap();
    let seq = spec.meta_usize("seq_len").unwrap();
    let vocab = spec.meta_usize("vocab").unwrap();
    assert_eq!(spec.meta("model"), Some("m2bert"));
    let mut gen = TokenGen::new(vocab, 11);
    let tokens = HostTensor::i32(gen.batch(batch, seq), &[batch, seq]);
    let ym = mon.call(&[tokens.clone()]).unwrap();
    let yb = base.call(&[tokens]).unwrap();
    assert_eq!(ym[0].shape, vec![batch, seq, vocab]);
    let err = ym[0].max_abs_diff(&yb[0]);
    assert!(err < 1e-3, "monarch/baseline model divergence {err:.3e}");
}

#[test]
fn sparse_kernel_ladder_is_served_natively() {
    // The Table 9 bench looks these up by name; the fleet must carry the
    // whole ladder plus the golden-checked small instance.
    let runtime = Runtime::native().unwrap();
    for tag in ["s0", "s50", "s75", "s84", "s91", "s94"] {
        let name = format!("conv_sparse_{tag}_n4096");
        let spec = runtime.manifest().get(&name).unwrap();
        assert_eq!(spec.meta("kind"), Some("conv_fwd"), "{name}");
        assert!(spec.meta("sparsity").is_some(), "{name}");
        assert!(spec.meta("flop_fraction").is_some(), "{name}");
    }
    let small = runtime.manifest().get("conv_sparse_s75_n1024").unwrap();
    assert!(small.golden_file.is_some(), "small sparse instance carries a golden");
}
