//! Property-based tests over the Rust substrates (no artifacts needed).
//!
//! Uses the in-crate property-testing framework (`flashfftconv::prop`) to
//! hammer the FFT/Monarch math, routing, batching, memory accounting, and
//! cost-model invariants with randomized cases.

use std::time::{Duration, Instant};

use flashfftconv::coordinator::batcher::{BatchPolicy, Batcher};
use flashfftconv::coordinator::memory;
use flashfftconv::coordinator::sparse::SparsityPattern;
use flashfftconv::costmodel::{self, A100};
use flashfftconv::fft;
use flashfftconv::prop::{self, gen};
use flashfftconv::util::Rng;

#[test]
fn prop_fft_conv_equals_direct() {
    prop::forall_ok(
        "fft conv == O(N^2) conv",
        1,
        prop::default_cases(),
        |rng| {
            let n = gen::pow2(rng, 2, 9);
            (gen::signal(rng, n), gen::signal(rng, n))
        },
        |(u, k)| {
            let err = fft::max_abs_diff(&fft::fft_conv(u, k), &fft::direct_conv(u, k));
            if err < 1e-7 {
                Ok(())
            } else {
                Err(format!("err {err}"))
            }
        },
    );
}

#[test]
fn prop_monarch_layout_conv_equals_direct() {
    prop::forall_ok(
        "monarch-layout conv == direct conv",
        2,
        prop::default_cases(),
        |rng| {
            let n1 = gen::pow2(rng, 1, 4);
            let n2 = gen::pow2(rng, 1, 4);
            (n1, n2, gen::signal(rng, n1 * n2), gen::signal(rng, n1 * n2))
        },
        |&(n1, n2, ref u, ref k)| {
            let uc: Vec<fft::Cpx> = u.iter().map(|&v| fft::Cpx::new(v, 0.0)).collect();
            let kc: Vec<fft::Cpx> = k.iter().map(|&v| fft::Cpx::new(v, 0.0)).collect();
            let prod: Vec<fft::Cpx> = fft::monarch_fft2(&uc, n1, n2)
                .iter()
                .zip(fft::monarch_fft2(&kc, n1, n2))
                .map(|(&a, b)| a * b)
                .collect();
            let y: Vec<f64> = fft::monarch_ifft2(&prod, n1, n2).iter().map(|c| c.re).collect();
            let err = fft::max_abs_diff(&y, &fft::direct_conv(u, k));
            if err < 1e-7 {
                Ok(())
            } else {
                Err(format!("({n1},{n2}) err {err}"))
            }
        },
    );
}

#[test]
fn prop_fft_parseval() {
    // Energy preservation: ||FFT(x)||^2 == N * ||x||^2.
    prop::forall_ok(
        "parseval",
        3,
        prop::default_cases(),
        |rng| {
            let n = gen::pow2(rng, 2, 10);
            gen::signal(rng, n)
        },
        |x| {
            let n = x.len() as f64;
            let t: f64 = x.iter().map(|v| v * v).sum();
            let f: f64 = fft::rfft_full(x).iter().map(|c| c.abs() * c.abs()).sum();
            if (f - n * t).abs() < 1e-6 * n * t.max(1.0) {
                Ok(())
            } else {
                Err(format!("time {t} freq {f}"))
            }
        },
    );
}

#[test]
fn prop_causal_conv_prefix_stability() {
    // Changing the suffix of the input never changes the causal prefix.
    prop::forall(
        "causality",
        4,
        prop::default_cases(),
        |rng| {
            let n = gen::pow2(rng, 3, 8);
            let cut = gen::index(rng, 1, n);
            (gen::signal(rng, n), gen::signal(rng, n), cut)
        },
        |(u, k, cut)| {
            let y1 = fft::causal_conv(u, k);
            let mut u2 = u.clone();
            for v in u2.iter_mut().skip(*cut) {
                *v += 42.0;
            }
            let y2 = fft::causal_conv(&u2, k);
            fft::max_abs_diff(&y1[..*cut], &y2[..*cut]) < 1e-7
        },
    );
}

#[test]
fn prop_batcher_conservation() {
    // Every pushed request is flushed exactly once, ids preserved.
    prop::forall(
        "batcher conserves requests",
        5,
        prop::default_cases(),
        |rng| {
            let batch = gen::index(rng, 1, 8);
            let pushes = gen::index(rng, 0, 40);
            (batch, pushes)
        },
        |&(batch, pushes)| {
            let mut b = Batcher::new(BatchPolicy {
                batch_size: batch,
                max_wait: Duration::from_millis(0),
            });
            let t = Instant::now();
            let ids: Vec<u64> = (0..pushes).map(|i| b.push(i, t)).collect();
            let mut seen = vec![];
            while let Some(batch) = b.flush(t + Duration::from_millis(1)) {
                assert!(batch.occupancy() <= batch.capacity);
                for p in batch.rows {
                    seen.push(p.id);
                }
            }
            seen == ids && b.is_empty()
        },
    );
}

#[test]
fn prop_memory_tracker_never_exceeds_budget() {
    prop::forall(
        "memory budget",
        6,
        prop::default_cases(),
        |rng| {
            let budget = 1 + rng.below(10_000);
            let ops: Vec<u64> = (0..50).map(|_| 1 + rng.below(500)).collect();
            (budget, ops)
        },
        |&(budget, ref ops)| {
            let t = memory::MemoryTracker::new(budget);
            let mut held = vec![];
            for (i, &sz) in ops.iter().enumerate() {
                if i % 3 == 2 {
                    if let Some(s) = held.pop() {
                        t.release(s);
                    }
                } else if t.reserve(sz) {
                    held.push(sz);
                }
                if t.used() > budget {
                    return false;
                }
            }
            t.peak() <= budget
        },
    );
}

#[test]
fn prop_cost_model_monotone_in_length() {
    // For a fixed order, cost never decreases with sequence length.
    prop::forall(
        "cost monotone",
        7,
        prop::default_cases(),
        |rng| {
            let logn = gen::index(rng, 8, 20);
            let p = gen::index(rng, 2, 4);
            (logn, p)
        },
        |&(logn, p)| {
            let a = costmodel::conv_cost(1 << logn, p, 1, 1, &A100);
            let b = costmodel::conv_cost(1 << (logn + 1), p, 1, 1, &A100);
            b > a
        },
    );
}

#[test]
fn prop_sparsity_fraction_and_flops_consistent() {
    prop::forall(
        "sparsity invariants",
        8,
        prop::default_cases(),
        |rng| {
            let n1 = gen::pow2(rng, 2, 6);
            let n2 = gen::pow2(rng, 2, 6);
            let kr = 1 + gen::index(rng, 0, n1);
            let kc = 1 + gen::index(rng, 0, n2);
            (n1, n2, kr, kc)
        },
        |&(n1, n2, kr, kc)| {
            let p = SparsityPattern::new(n1, n2, kr, kc).unwrap();
            let s = p.sparsity_fraction();
            let f = p.flop_fraction();
            (0.0..=1.0).contains(&s)
                && f > 0.0
                && f <= 1.0 + 1e-12
                && p.ideal_speedup() >= 1.0 - 1e-12
        },
    );
}

#[test]
fn prop_rust_and_kernel_factorizations_agree() {
    // monarch_factors mirror: product and balance invariants.
    prop::forall(
        "factorization invariants",
        9,
        prop::default_cases(),
        |rng| {
            let logn = gen::index(rng, 4, 22);
            let order = gen::index(rng, 2, 4.min(logn));
            (1usize << logn, order)
        },
        |&(n, order)| {
            let f = fft::monarch_factors(n, order);
            f.iter().product::<usize>() == n
                && f.len() == order
                && *f.iter().max().unwrap() <= 2 * f.iter().min().unwrap()
        },
    );
}

#[test]
fn prop_monarch3_layout_matches_radix2_fft() {
    // Order-3 decomposition == radix-2 FFT under the order-3 permutation
    // (the `monarch_order2`-style digit map, one level deeper).
    prop::forall_ok(
        "order-3 monarch == permuted FFT",
        10,
        prop::default_cases(),
        |rng| {
            let n1 = gen::pow2(rng, 1, 3);
            let n2 = gen::pow2(rng, 1, 3);
            let n3 = gen::pow2(rng, 1, 3);
            let x = gen::signal(rng, n1 * n2 * n3);
            (n1, n2, n3, x)
        },
        |&(n1, n2, n3, ref x)| {
            let xc: Vec<fft::Cpx> = x.iter().map(|&v| fft::Cpx::new(v, 0.0)).collect();
            let got = fft::monarch_fft3(&xc, n1, n2, n3);
            let full = fft::fft(&xc, false);
            let order = fft::monarch_order3(n1, n2, n3);
            for (j, &f) in order.iter().enumerate() {
                let err = (got[j] - full[f]).abs();
                if err > 1e-7 {
                    return Err(format!("({n1},{n2},{n3}) slot {j}: err {err}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_causal_conv_prefix_invariance_at_random_lengths() {
    // Causality must hold at arbitrary (non-power-of-two) lengths: the
    // suffix of the input never influences the causal prefix.
    prop::forall(
        "causality at random lengths",
        11,
        prop::default_cases(),
        |rng| {
            let n = 2 + gen::index(rng, 0, 300); // any length in [2, 302)
            let cut = gen::index(rng, 1, n);
            (gen::signal(rng, n), gen::signal(rng, n), cut)
        },
        |(u, k, cut)| {
            let y1 = fft::causal_conv(u, k);
            let mut u2 = u.clone();
            for v in u2.iter_mut().skip(*cut) {
                *v += 42.0;
            }
            let y2 = fft::causal_conv(&u2, k);
            fft::max_abs_diff(&y1[..*cut], &y2[..*cut]) < 1e-7
        },
    );
}

#[test]
fn prop_full_mask_spectrum_equals_dense_conv() {
    // Frequency-sparse conv with an all-ones mask is exactly dense conv.
    prop::forall_ok(
        "full-mask sparse spectrum == dense conv",
        12,
        prop::default_cases(),
        |rng| {
            let n = gen::pow2(rng, 2, 9);
            (gen::signal(rng, n), gen::signal(rng, n))
        },
        |(u, k)| {
            let kf = fft::rfft_full(k);
            let sparse = fft::fft_conv_spectrum(u, &kf);
            let dense = fft::fft_conv(u, k);
            let err = fft::max_abs_diff(&sparse, &dense);
            if err < 1e-7 {
                Ok(())
            } else {
                Err(format!("err {err}"))
            }
        },
    );
}

#[test]
fn prop_masked_spectrum_differs_from_dense_when_bins_dropped() {
    // Complement check: zeroing occupied bins must change the output
    // (guards against the mask being silently ignored).
    prop::forall(
        "masked bins change the conv",
        13,
        prop::default_cases(),
        |rng| {
            let n = gen::pow2(rng, 3, 8);
            (gen::signal(rng, n), gen::signal(rng, n))
        },
        |(u, k)| {
            let mut kf = fft::rfft_full(k);
            for z in kf.iter_mut().skip(kf.len() / 2) {
                *z = fft::Cpx::ZERO;
            }
            let sparse = fft::fft_conv_spectrum(u, &kf);
            let dense = fft::fft_conv(u, k);
            fft::max_abs_diff(&sparse, &dense) > 1e-9
        },
    );
}

#[test]
fn prop_planned_order2_matches_naive_monarch() {
    // Planned GEMM execution == the naive trig-in-the-loop oracle, both
    // directions, at random factor shapes and batched rows.
    prop::forall_ok(
        "planned order-2 == naive monarch_fft2/ifft2",
        14,
        prop::default_cases(),
        |rng| {
            let n1 = gen::pow2(rng, 1, 4);
            let n2 = gen::pow2(rng, 1, 4);
            let n = n1 * n2;
            (n1, n2, gen::signal(rng, 2 * n), gen::signal(rng, 2 * n))
        },
        |&(n1, n2, ref sre, ref sim)| {
            let n = n1 * n2;
            let p = fft::plan::FftPlan::new(n, vec![n1, n2]).map_err(|e| format!("{e:#}"))?;
            let rows = 2usize;
            let mut re = sre.clone();
            let mut im = sim.clone();
            p.forward(&mut re, &mut im, rows);
            for r in 0..rows {
                let x: Vec<fft::Cpx> = (0..n)
                    .map(|i| fft::Cpx::new(sre[r * n + i], sim[r * n + i]))
                    .collect();
                let want = fft::monarch_fft2(&x, n1, n2);
                for (j, w) in want.iter().enumerate() {
                    let d = (re[r * n + j] - w.re).abs().max((im[r * n + j] - w.im).abs());
                    if d > 1e-8 {
                        return Err(format!("fwd ({n1},{n2}) row {r} slot {j}: err {d}"));
                    }
                }
            }
            // Inverse against the naive inverse, per batched row.
            let wants: Vec<Vec<fft::Cpx>> = (0..rows)
                .map(|r| {
                    let spec: Vec<fft::Cpx> = (0..n)
                        .map(|i| fft::Cpx::new(re[r * n + i], im[r * n + i]))
                        .collect();
                    fft::monarch_ifft2(&spec, n1, n2)
                })
                .collect();
            p.inverse(&mut re, &mut im, rows);
            for (r, want) in wants.iter().enumerate() {
                for (j, w) in want.iter().enumerate() {
                    let d =
                        (re[r * n + j] - w.re).abs().max((im[r * n + j] - w.im).abs());
                    if d > 1e-8 {
                        return Err(format!("inv ({n1},{n2}) row {r} slot {j}: err {d}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_planned_order3_matches_naive_monarch() {
    prop::forall_ok(
        "planned order-3 == naive monarch_fft3/ifft3",
        15,
        prop::default_cases(),
        |rng| {
            let n1 = gen::pow2(rng, 1, 3);
            let n2 = gen::pow2(rng, 1, 3);
            let n3 = gen::pow2(rng, 1, 3);
            let n = n1 * n2 * n3;
            (n1, n2, n3, gen::signal(rng, n), gen::signal(rng, n))
        },
        |&(n1, n2, n3, ref sre, ref sim)| {
            let n = n1 * n2 * n3;
            let p = fft::plan::FftPlan::new(n, vec![n1, n2, n3])
                .map_err(|e| format!("{e:#}"))?;
            let x: Vec<fft::Cpx> =
                (0..n).map(|i| fft::Cpx::new(sre[i], sim[i])).collect();
            let mut re = sre.clone();
            let mut im = sim.clone();
            p.forward(&mut re, &mut im, 1);
            let want = fft::monarch_fft3(&x, n1, n2, n3);
            for (j, w) in want.iter().enumerate() {
                let d = (re[j] - w.re).abs().max((im[j] - w.im).abs());
                if d > 1e-8 {
                    return Err(format!("fwd ({n1},{n2},{n3}) slot {j}: err {d}"));
                }
            }
            let spec: Vec<fft::Cpx> =
                (0..n).map(|i| fft::Cpx::new(re[i], im[i])).collect();
            let want = fft::monarch_ifft3(&spec, n1, n2, n3);
            p.inverse(&mut re, &mut im, 1);
            for (j, w) in want.iter().enumerate() {
                let d = (re[j] - w.re).abs().max((im[j] - w.im).abs());
                if d > 1e-8 {
                    return Err(format!("inv ({n1},{n2},{n3}) slot {j}: err {d}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_planned_r2c_conv_matches_naive_conv() {
    // The full planned real path (r2c -> half-spectrum product -> c2r)
    // == the naive fused-FFT convolution, at random lengths and orders.
    prop::forall_ok(
        "planned r2c conv == naive fft_conv",
        16,
        prop::default_cases(),
        |rng| {
            let n = gen::pow2(rng, 3, 10);
            let order = 1 + gen::index(rng, 0, 3);
            (n, order, gen::signal(rng, n), gen::signal(rng, n))
        },
        |&(n, order, ref u, ref k)| {
            let rp = fft::plan::real_plan(n, order).map_err(|e| format!("{e:#}"))?;
            let (kre, kim) = rp.rfft_rows(k, 1);
            let y = rp.conv_rows(u, 1, &kre, &kim, |_| 0);
            let err = fft::max_abs_diff(&y, &fft::fft_conv(u, k));
            if err < 1e-8 {
                Ok(())
            } else {
                Err(format!("n={n} order={order}: err {err}"))
            }
        },
    );
}

#[test]
fn prop_planned_block_inverse_matches_naive() {
    prop::forall_ok(
        "planned block inverse == monarch_ifft2_block",
        17,
        prop::default_cases(),
        |rng| {
            let n1 = gen::pow2(rng, 1, 4);
            let n2 = gen::pow2(rng, 1, 4);
            let kr = 1 + gen::index(rng, 0, n1);
            let kc = 1 + gen::index(rng, 0, n2);
            (n1, n2, kr, kc, gen::signal(rng, n1 * n2), gen::signal(rng, n1 * n2))
        },
        |&(n1, n2, kr, kc, ref sre, ref sim)| {
            let n = n1 * n2;
            let p = fft::plan::FftPlan::new(n, vec![n1, n2]).map_err(|e| format!("{e:#}"))?;
            let mut spec: Vec<fft::Cpx> =
                (0..n).map(|i| fft::Cpx::new(sre[i], sim[i])).collect();
            for r in 0..n1 {
                for c in 0..n2 {
                    if r >= kr || c >= kc {
                        spec[r * n2 + c] = fft::Cpx::ZERO;
                    }
                }
            }
            let mut re: Vec<f64> = spec.iter().map(|z| z.re).collect();
            let mut im: Vec<f64> = spec.iter().map(|z| z.im).collect();
            p.inverse2_block(&mut re, &mut im, 1, kr, kc);
            let want = fft::monarch_ifft2_block(&spec, n1, n2, kr, kc);
            for (j, w) in want.iter().enumerate() {
                let d = (re[j] - w.re).abs().max((im[j] - w.im).abs());
                if d > 1e-9 {
                    return Err(format!("({n1},{n2},{kr},{kc}) slot {j}: err {d}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_workspace_interleaving_is_bitwise_identical() {
    // One shared ConvWorkspace carried across every generated case:
    // mixed lengths, orders, and batch sizes interleave through it (the
    // serving shape — one workspace per shard worker, many buckets), and
    // every result must match the fresh-alloc convenience wrappers BIT
    // FOR BIT, in both the conv path and the raw complex transforms.
    let mut ws = fft::workspace::ConvWorkspace::new();
    prop::forall_ok(
        "shared-workspace execution == fresh-alloc wrappers (bitwise)",
        31,
        prop::default_cases(),
        |rng| {
            let n = gen::pow2(rng, 4, 9);
            let order = 1 + gen::index(rng, 0, 3);
            let rows = 1 + gen::index(rng, 0, 4);
            (n, order, rows, gen::signal(rng, rows * n), gen::signal(rng, n))
        },
        move |&(n, order, rows, ref u, ref k)| {
            // Real conv path.
            let rp = fft::plan::real_plan(n, order).map_err(|e| format!("{e:#}"))?;
            let (kre, kim) = rp.rfft_rows(k, 1);
            let want = rp.conv_rows(u, rows, &kre, &kim, |_| 0);
            let mut got = vec![0.0f64; rows * n];
            rp.conv_rows_into(u, rows, &kre, &kim, |_| 0, &mut got, &mut ws);
            if !want.iter().zip(&got).all(|(a, b)| a.to_bits() == b.to_bits()) {
                return Err(format!("n={n} order={order} rows={rows}: conv diverged bitwise"));
            }
            // Complex forward/inverse through the same shared workspace.
            let p = fft::plan::plan(n, order.min(2)).map_err(|e| format!("{e:#}"))?;
            let mut re_a: Vec<f64> = u[..rows * n].to_vec();
            let mut im_a: Vec<f64> = vec![0.25; rows * n];
            let mut re_b = re_a.clone();
            let mut im_b = im_a.clone();
            p.forward(&mut re_a, &mut im_a, rows);
            p.forward_ws(&mut re_b, &mut im_b, rows, &mut ws);
            p.inverse(&mut re_a, &mut im_a, rows);
            p.inverse_ws(&mut re_b, &mut im_b, rows, &mut ws);
            if !re_a.iter().zip(&re_b).all(|(a, b)| a.to_bits() == b.to_bits())
                || !im_a.iter().zip(&im_b).all(|(a, b)| a.to_bits() == b.to_bits())
            {
                return Err(format!("n={n} rows={rows}: transform diverged bitwise"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_rng_uniform_bounds() {
    let mut rng = Rng::new(123);
    for _ in 0..10_000 {
        let v = rng.uniform();
        assert!((0.0..1.0).contains(&v));
    }
}

#[test]
fn prop_fleet_reply_pairing_across_shards() {
    // Any interleaving of submits/flushes across shards must preserve
    // per-request reply pairing (no cross-wired replies). Convolution is
    // linear, so a constant-valued input row c*ones must come back as
    // c * y1 where y1 is the fleet's response to all-ones — a reply wired
    // to the wrong request has a wildly wrong scale.
    use flashfftconv::coordinator::fleet::{FleetConfig, FleetDispatcher, FleetError};
    use flashfftconv::coordinator::router::ConvKind;
    use flashfftconv::coordinator::service::ConvRequest;
    use flashfftconv::runtime::BackendConfig;

    const HEADS: usize = 16;
    let fleet = FleetDispatcher::conv(
        BackendConfig::NativeRowThreads(1),
        "monarch",
        FleetConfig {
            shards: 3,
            max_inflight: 16,
            policy: BatchPolicy { batch_size: 2, max_wait: Duration::from_millis(1) },
        },
    )
    .expect("fleet starts");

    let lens = [256usize, 200, 1024];
    let ones: Vec<Vec<f32>> = lens
        .iter()
        .map(|&len| {
            fleet
                .call(ConvRequest {
                    kind: ConvKind::Forward,
                    len,
                    streams: vec![vec![1.0; HEADS * len]], chunk_tx: None
                })
                .expect("baseline all-ones conv")
        })
        .collect();

    prop::forall_ok(
        "fleet preserves reply pairing",
        31,
        prop::default_cases(),
        |rng| {
            let burst = 1 + gen::index(rng, 0, 20);
            let picks: Vec<(usize, f64)> = (0..burst)
                .map(|_| (gen::index(rng, 0, lens.len()), 1.0 + gen::index(rng, 0, 97) as f64))
                .collect();
            picks
        },
        |picks| {
            let mut pending = vec![];
            for &(li, c) in picks {
                let len = lens[li];
                let mut req = ConvRequest {
                    kind: ConvKind::Forward,
                    len,
                    streams: vec![vec![c as f32; HEADS * len]], chunk_tx: None
                };
                loop {
                    match fleet.try_submit(req) {
                        Ok(rx) => {
                            pending.push((li, c, rx));
                            break;
                        }
                        Err((r, FleetError::Busy)) => {
                            req = r;
                            // Flush pressure: consume the oldest pending.
                            if pending.is_empty() {
                                std::thread::sleep(Duration::from_micros(100));
                            } else {
                                let (li, c, rx) = pending.remove(0);
                                check_reply(&ones, &lens, li, c, rx)?;
                            }
                        }
                        Err((_, e)) => return Err(format!("submit failed: {e}")),
                    }
                }
            }
            // Consume in reverse order to stress out-of-order clients.
            while let Some((li, c, rx)) = pending.pop() {
                check_reply(&ones, &lens, li, c, rx)?;
            }
            Ok(())
        },
    );

    fn check_reply(
        ones: &[Vec<f32>],
        lens: &[usize],
        li: usize,
        c: f64,
        rx: std::sync::mpsc::Receiver<flashfftconv::coordinator::fleet::FleetReply>,
    ) -> Result<(), String> {
        let y = rx
            .recv()
            .map_err(|_| "lost reply".to_string())?
            .map_err(|e| format!("conv failed: {e}"))?
            .data;
        let base = &ones[li];
        if y.len() != base.len() {
            return Err(format!("reply length {} != expected {}", y.len(), base.len()));
        }
        let scale = base.iter().map(|v| v.abs() as f64).fold(1.0f64, f64::max) * c;
        for (j, (&got, &b)) in y.iter().zip(base.iter()).enumerate() {
            let want = c * b as f64;
            if (got as f64 - want).abs() > 1e-3 * scale.max(1.0) {
                return Err(format!(
                    "len {} slot {j}: got {got}, want {want:.4} (c={c}) — cross-wired reply?",
                    lens[li]
                ));
            }
        }
        Ok(())
    }
}

#[test]
fn prop_latency_quantiles_monotone_and_bracketing() {
    // LatencyHistogram::quantile_ms invariants over random sample sets:
    // monotone in q, p0/p100 bracket the recorded samples (up to the
    // documented bucket-upper-bound rounding), and samples past the last
    // bucket report the finite overflow bound instead of a misleading
    // in-range value.
    use flashfftconv::coordinator::fleet::LatencyHistogram;

    let overflow_ms = LatencyHistogram::overflow_bound_ms();
    assert!(overflow_ms.is_finite() && overflow_ms > 0.0);

    prop::forall_ok(
        "latency quantiles monotone and bracketing",
        41,
        prop::default_cases(),
        |rng| {
            let n = 1 + gen::index(rng, 0, 64);
            (0..n)
                .map(|_| {
                    // Mix scales: sub-µs, µs..s, and past-the-last-bucket
                    // values (bucket 39 starts at 2^38 µs ≈ 76 hours).
                    match gen::index(rng, 0, 4) {
                        0 => gen::index(rng, 0, 1_000) as u64,
                        1 => 1_000u64 << gen::index(rng, 0, 20),
                        2 => 1_000_000u64 * (1 + gen::index(rng, 0, 10_000) as u64),
                        _ => u64::MAX - gen::index(rng, 0, 1_000_000) as u64,
                    }
                })
                .collect::<Vec<u64>>()
        },
        |samples| {
            let h = LatencyHistogram::default();
            for &ns in samples {
                h.record(ns);
            }
            let counts = h.counts();
            if counts.iter().sum::<u64>() != samples.len() as u64 {
                return Err("every sample must land in exactly one bucket".into());
            }
            let qs = [1e-9, 0.01, 0.25, 0.50, 0.75, 0.90, 0.99, 0.999, 1.0];
            let mut prev = 0.0f64;
            for &q in &qs {
                let v = LatencyHistogram::quantile_ms(&counts, q);
                if !v.is_finite() || v < 0.0 {
                    return Err(format!("q={q}: non-finite quantile {v}"));
                }
                if v + 1e-12 < prev {
                    return Err(format!("quantiles not monotone at q={q}: {v} < {prev}"));
                }
                if v > overflow_ms {
                    return Err(format!("q={q}: {v} exceeds overflow bound {overflow_ms}"));
                }
                prev = v;
            }
            let min_ms = samples.iter().min().map(|&ns| ns as f64 / 1e6).unwrap();
            let max_ms = samples.iter().max().map(|&ns| ns as f64 / 1e6).unwrap();
            let p0 = LatencyHistogram::quantile_ms(&counts, 1e-9);
            let p100 = LatencyHistogram::quantile_ms(&counts, 1.0);
            // Bucket upper bounds: p0 covers the smallest sample (within
            // its 2x-wide bucket, floored at the <1µs bucket), p100
            // covers the largest (clamped to the overflow bound).
            if p0 + 1e-12 < min_ms.min(overflow_ms) {
                return Err(format!("p0 {p0} below smallest sample {min_ms}"));
            }
            if p0 > (2.0 * min_ms).max(1e-3).min(overflow_ms) {
                return Err(format!("p0 {p0} far above smallest sample {min_ms}"));
            }
            if p100 + 1e-12 < max_ms.min(overflow_ms) {
                return Err(format!("p100 {p100} below largest sample {max_ms}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_chunked_overlap_add_matches_monolithic_causal_conv() {
    use flashfftconv::fft::chunked::ChunkedConvPlan;
    use flashfftconv::fft::workspace::ConvWorkspace;
    prop::forall_ok(
        "chunked overlap-add == monolithic causal conv",
        21,
        prop::default_cases(),
        |rng| {
            let c = gen::pow2(rng, 4, 8);
            // Edge-heavy geometry: single-chunk (n <= c), exact divisor,
            // and random non-divisor tails.
            let n = match gen::index(rng, 0, 4) {
                0 => gen::index(rng, 1, c + 1),
                1 => c * gen::index(rng, 1, 5),
                _ => gen::index(rng, 1, 5 * c),
            };
            // Edge-heavy filters: one tap, full-chunk taps, or interior.
            let l = match gen::index(rng, 0, 4) {
                0 => 1,
                1 => c,
                _ => gen::index(rng, 1, c + 1),
            };
            (gen::signal(rng, n), gen::signal(rng, l), c)
        },
        |(u, k, c)| {
            let (n, l) = (u.len(), k.len());
            let plan = ChunkedConvPlan::with_order(n, l, *c, Some(2))
                .map_err(|e| format!("plan: {e}"))?;
            let (kre, kim) = plan.filter_spectrum(k).map_err(|e| format!("spec: {e}"))?;
            let mut got = vec![0.0; n];
            plan.conv_into(u, &kre, &kim, &mut got, &mut ConvWorkspace::new())
                .map_err(|e| format!("conv: {e}"))?;
            let m = n.max(l);
            let mut up = u.clone();
            up.resize(m, 0.0);
            let mut kp = k.clone();
            kp.resize(m, 0.0);
            let want = &fft::causal_conv(&up, &kp)[..n];
            let err = fft::max_abs_diff(&got, want);
            if err < 1e-8 {
                Ok(())
            } else {
                Err(format!("n={n} l={l} c={c}: err {err}"))
            }
        },
    );
}

#[test]
fn prop_chunked_bitwise_per_chunk_size_and_tolerant_across() {
    use flashfftconv::fft::chunked::ChunkedConvPlan;
    use flashfftconv::fft::workspace::ConvWorkspace;
    prop::forall_ok(
        "chunked conv: bitwise per chunk size, tolerance across sizes",
        22,
        prop::default_cases(),
        |rng| {
            let c1 = gen::pow2(rng, 5, 7);
            let c2 = gen::pow2(rng, 5, 7);
            let n = gen::index(rng, 1, 6 * c1);
            let l = gen::index(rng, 1, c1.min(c2) + 1);
            (gen::signal(rng, n), gen::signal(rng, l), c1, c2)
        },
        |(u, k, c1, c2)| {
            let (n, l) = (u.len(), k.len());
            let run = |c: usize, ws: &mut ConvWorkspace| -> Result<Vec<f64>, String> {
                let plan = ChunkedConvPlan::with_order(n, l, c, Some(2))
                    .map_err(|e| format!("plan: {e}"))?;
                let (kre, kim) = plan.filter_spectrum(k).map_err(|e| format!("spec: {e}"))?;
                let mut y = vec![0.0; n];
                plan.conv_into(u, &kre, &kim, &mut y, ws).map_err(|e| format!("conv: {e}"))?;
                Ok(y)
            };
            // Same chunk size, cold workspace vs one dirtied by a prior
            // pass at a *different* chunk size: bitwise identical (the
            // workspace take() zeroing contract).
            let a = run(*c1, &mut ConvWorkspace::new())?;
            let mut ws = ConvWorkspace::new();
            let b_other = run(*c2, &mut ws)?;
            let b = run(*c1, &mut ws)?;
            for (i, (x, y)) in a.iter().zip(&b).enumerate() {
                if x.to_bits() != y.to_bits() {
                    return Err(format!(
                        "c={c1}: bit mismatch at {i} ({x:e} vs {y:e}) after a c={c2} pass"
                    ));
                }
            }
            // Different chunk sizes agree within accumulation tolerance.
            let err = fft::max_abs_diff(&a, &b_other);
            if err < 1e-8 {
                Ok(())
            } else {
                Err(format!("n={n} l={l} c1={c1} c2={c2}: cross-chunk err {err}"))
            }
        },
    );
}
