//! Integration tests over the native backend — no `make artifacts`, no
//! Python step, no skips.
//!
//! Each test exercises the full artifact path (manifest → engine →
//! fixture operands → execute) and checks cross-implementation behaviour:
//! golden replay (Monarch engines vs the radix-2 oracle transcripts),
//! conv outputs vs the O(N²) `direct_conv` oracle on every routed bucket,
//! training-state round-trips with descending loss, partial/sparse
//! evaluation, and the serving path.

use flashfftconv::coordinator::partial::{filter_mask, ExtensionPlan};
use flashfftconv::coordinator::router::{ConvKind, Router};
use flashfftconv::coordinator::service::{ConvRequest, ConvService};
use flashfftconv::coordinator::BatchPolicy;
use flashfftconv::runtime::{golden, BackendConfig, HostTensor, Runtime};
use flashfftconv::trainer::data::TokenGen;
use flashfftconv::util::Rng;

fn native() -> Runtime {
    Runtime::native().expect("native backend constructs")
}

#[test]
fn golden_replay_all_declared_transcripts() {
    let runtime = native();
    let names: Vec<String> = runtime
        .manifest()
        .artifacts
        .values()
        .filter(|a| a.golden_file.is_some())
        .map(|a| a.name.clone())
        .collect();
    assert!(names.len() >= 4, "expected several goldens, got {names:?}");
    for name in names {
        let spec = runtime.manifest().get(&name).unwrap().clone();
        let g = golden::load(&runtime, &spec).unwrap().unwrap();
        let mut art = runtime.load(&name).unwrap();
        let outs = art.call(&g.inputs).unwrap();
        for (got, want) in outs.iter().zip(&g.outputs) {
            let err = got.max_abs_diff(want);
            assert!(err < 1e-4, "{name}: golden replay err {err:.3e}");
        }
    }
}

/// Acceptance bar: native conv output matches the `direct_conv` oracle to
/// 1e-4 on every bucket the router serves, for every kind and variant.
/// The O(N²) oracle is used up to 1024 points; beyond that the (already
/// direct-conv-verified) radix-2 FFT oracle stands in to keep the test
/// fast in debug builds.
#[test]
fn every_routed_bucket_matches_direct_conv_oracle() {
    let runtime = native();
    for variant in ["monarch", "baseline"] {
        let router = Router::from_manifest(runtime.manifest(), variant).unwrap();
        for kind in [ConvKind::Forward, ConvKind::Causal, ConvKind::Gated] {
            for bucket in router.bucket_lens(kind) {
                let route = router.route(kind, bucket).unwrap();
                assert_eq!(route.padding, 0);
                let (b, h, n) = (route.batch, route.heads, bucket);
                let mut art = runtime.load(&route.artifact).unwrap();
                let mut rng = Rng::new(0xB0C5 ^ n as u64);
                let u = rng.normal_vec(b * h * n);
                let k = rng.normal_vec(h * n);
                let mut inputs = vec![HostTensor::f32(u.clone(), &[b, h, n])];
                let (v, w) = if kind == ConvKind::Gated {
                    let v = rng.normal_vec(b * h * n);
                    let w = rng.normal_vec(b * h * n);
                    inputs.push(HostTensor::f32(v.clone(), &[b, h, n]));
                    inputs.push(HostTensor::f32(w.clone(), &[b, h, n]));
                    (v, w)
                } else {
                    (vec![], vec![])
                };
                inputs.push(HostTensor::f32(k.clone(), &[h, n]));
                let y = art.call(&inputs).unwrap();
                let y = y[0].as_f32();
                // Check the first and last rows against the oracle.
                for &(bi, hi) in &[(0usize, 0usize), (b - 1, h - 1)] {
                    let off = (bi * h + hi) * n;
                    let krow: Vec<f64> =
                        k[hi * n..(hi + 1) * n].iter().map(|&x| x as f64).collect();
                    let urow: Vec<f64> = if kind == ConvKind::Gated {
                        u[off..off + n]
                            .iter()
                            .zip(&w[off..off + n])
                            .map(|(&a, &c)| a as f64 * c as f64)
                            .collect()
                    } else {
                        u[off..off + n].iter().map(|&x| x as f64).collect()
                    };
                    let conv = match (kind, n <= 1024) {
                        (ConvKind::Causal, true) => (0..n)
                            .map(|t| (0..=t).map(|d| urow[t - d] * krow[d]).sum())
                            .collect::<Vec<f64>>(),
                        (ConvKind::Causal, false) => flashfftconv::fft::causal_conv(&urow, &krow),
                        (_, true) => flashfftconv::fft::direct_conv(&urow, &krow),
                        (_, false) => flashfftconv::fft::fft_conv(&urow, &krow),
                    };
                    for (t, &want) in conv.iter().enumerate() {
                        let got = y[off + t] as f64;
                        let want = if kind == ConvKind::Gated {
                            v[off + t] as f64 * want
                        } else {
                            want
                        };
                        assert!(
                            (got - want).abs() < 1e-4,
                            "{variant}/{kind:?}/n{n} row ({bi},{hi}) t={t}: {got} vs {want}"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn monarch_and_baseline_variants_agree() {
    // Two independent engine implementations of the same artifact
    // signature must produce the same convolution.
    let runtime = native();
    let (b, h, n) = (2usize, 16usize, 256usize);
    let mut rng = Rng::new(77);
    let inputs = vec![
        HostTensor::f32(rng.normal_vec(b * h * n), &[b, h, n]),
        HostTensor::f32(rng.normal_vec(h * n), &[h, n]),
    ];
    let ym = runtime.load("conv_fwd_monarch_n256").unwrap().call(&inputs).unwrap();
    let yb = runtime.load("conv_fwd_baseline_n256").unwrap().call(&inputs).unwrap();
    let err = ym[0].max_abs_diff(&yb[0]);
    assert!(err < 1e-4, "variant divergence {err:.3e}");
}

#[test]
fn train_step_state_roundtrip_descends() {
    let runtime = native();
    let mut art = runtime.load("lm_tiny_train").unwrap();
    let spec = art.spec().clone();
    let batch = spec.meta_usize("batch").unwrap();
    let seq = spec.meta_usize("seq_len").unwrap();
    let vocab = spec.meta_usize("vocab").unwrap();
    let embed_before = art.state("param.embed").unwrap();
    let mut gen = TokenGen::new(vocab, 3);
    let mut losses = vec![];
    for _ in 0..12 {
        let tokens = gen.batch(batch, seq + 1);
        let outs = art.step(&[HostTensor::i32(tokens, &[batch, seq + 1])]).unwrap();
        let loss = outs.last().unwrap().item();
        assert!(loss.is_finite(), "loss must stay finite, got {loss}");
        losses.push(loss);
    }
    let head: f64 = losses[..3].iter().sum::<f64>() / 3.0;
    let tail: f64 = losses[losses.len() - 3..].iter().sum::<f64>() / 3.0;
    assert!(tail < head, "loss should descend: {losses:?}");
    // The state round-trip must actually move the parameters.
    let embed_after = art.state("param.embed").unwrap();
    assert!(embed_after.max_abs_diff(&embed_before) > 0.0);
    // And the step counter counts calls.
    assert!((art.state("step").unwrap().item() - 12.0).abs() < 1e-6);
}

#[test]
fn eval_kmask_full_mask_matches_tight_band() {
    let runtime = native();
    let mut art = runtime.load("lm_eval_kmask").unwrap();
    let spec = art.spec().clone();
    let batch = spec.meta_usize("batch").unwrap();
    let seq = spec.meta_usize("seq_len").unwrap();
    let vocab = spec.meta_usize("vocab").unwrap();
    let mut gen = TokenGen::new(vocab, 4);
    let tokens = HostTensor::i32(gen.batch(batch, seq + 1), &[batch, seq + 1]);
    let full = art
        .call(&[tokens.clone(), HostTensor::f32(filter_mask(seq, seq), &[seq])])
        .unwrap()[0]
        .item();
    // Untrained model: loss near ln(vocab).
    assert!((full - (vocab as f64).ln()).abs() < 0.7, "loss {full}");
    // Truncating the filter changes the loss but keeps it finite/sane.
    let half = art
        .call(&[tokens, HostTensor::f32(filter_mask(seq, seq / 8), &[seq])])
        .unwrap()[0]
        .item();
    assert!(half.is_finite() && (half - full).abs() < 2.0);
}

#[test]
fn service_conv_matches_native_fft_oracle() {
    let policy = BatchPolicy { batch_size: 2, max_wait: std::time::Duration::from_millis(2) };
    let service = ConvService::start(BackendConfig::Native, "monarch", policy).unwrap();
    let (h, len) = (16usize, 256usize);
    let mut rng = Rng::new(5);
    let k: Vec<f32> = rng.normal_vec(h * len);
    service.set_filter(ConvKind::Forward, len, k.clone()).unwrap();
    let u: Vec<f32> = rng.normal_vec(h * len);
    let y = service
        .call(ConvRequest { kind: ConvKind::Forward, len, streams: vec![u.clone()], chunk_tx: None })
        .unwrap();
    assert_eq!(y.len(), h * len);
    for hi in 0..h {
        let urow: Vec<f64> = u[hi * len..(hi + 1) * len].iter().map(|&x| x as f64).collect();
        let krow: Vec<f64> = k[hi * len..(hi + 1) * len].iter().map(|&x| x as f64).collect();
        let want = flashfftconv::fft::fft_conv(&urow, &krow);
        for (g, w) in y[hi * len..(hi + 1) * len].iter().zip(&want) {
            assert!((*g as f64 - w).abs() < 1e-4, "head {hi}");
        }
    }
    let s = service.stats();
    assert_eq!(s.requests.load(std::sync::atomic::Ordering::Relaxed), 1);
}

#[test]
fn service_pads_shorter_requests() {
    let policy = BatchPolicy { batch_size: 2, max_wait: std::time::Duration::from_millis(1) };
    let service = ConvService::start(BackendConfig::Native, "monarch", policy).unwrap();
    let (h, len) = (16usize, 200usize); // pads up to the 512 causal bucket
    let mut rng = Rng::new(6);
    let u: Vec<f32> = rng.normal_vec(h * len);
    let y = service
        .call(ConvRequest { kind: ConvKind::Causal, len, streams: vec![u.clone()], chunk_tx: None })
        .unwrap();
    assert_eq!(y.len(), h * len);
    assert!(y.iter().all(|v| v.is_finite()));
}

#[test]
fn router_buckets_match_native_manifest() {
    let runtime = native();
    let router = Router::from_manifest(runtime.manifest(), "monarch").unwrap();
    let lens = router.bucket_lens(ConvKind::Forward);
    assert!(lens.contains(&256) && lens.contains(&1024) && lens.contains(&4096));
    let lens_c = router.bucket_lens(ConvKind::Causal);
    assert!(lens_c.contains(&128) && lens_c.contains(&512));
    let lens_g = router.bucket_lens(ConvKind::Gated);
    assert!(lens_g.contains(&256) && lens_g.contains(&1024));
}

#[test]
fn extension_plan_against_dna_eval() {
    let runtime = native();
    let mut art = runtime.load("dna_eval").unwrap();
    let spec = art.spec().clone();
    let context = spec.meta_usize("seq_len").unwrap();
    let kmask_len = spec
        .inputs
        .iter()
        .find(|i| i.spec.name == "kmask")
        .map(|i| i.spec.numel())
        .unwrap();
    let total = 2 * context;
    let plan = ExtensionPlan::new(total, context, context / 2).unwrap();
    let mut gen = flashfftconv::trainer::data::DnaGen::new(64, 9);
    let seq = gen.sequence(total + 1);
    let mask = vec![1.0f32; kmask_len];
    let mut losses = vec![];
    for w in &plan.windows {
        let window: Vec<i32> = seq[w.start..w.start + context + 1].to_vec();
        let outs = art
            .call(&[
                HostTensor::i32(window, &[1, context + 1]),
                HostTensor::f32(mask.clone(), &[kmask_len]),
            ])
            .unwrap();
        losses.push(outs[0].item());
    }
    let combined = plan.combine_losses(&losses);
    assert!(combined.is_finite() && combined > 0.0 && combined < 3.0, "loss {combined}");
}

#[test]
fn sparse_eval_artifacts_stay_sane() {
    let runtime = native();
    let mut base = runtime.load("lm_eval_kmask").unwrap();
    let spec = base.spec().clone();
    let (batch, seq, vocab) = (
        spec.meta_usize("batch").unwrap(),
        spec.meta_usize("seq_len").unwrap(),
        spec.meta_usize("vocab").unwrap(),
    );
    let mut gen = TokenGen::new(vocab, 10);
    let tokens = HostTensor::i32(gen.batch(batch, seq + 1), &[batch, seq + 1]);
    let dense =
        base.call(&[tokens.clone(), HostTensor::f32(vec![1.0; seq], &[seq])]).unwrap()[0].item();
    for name in ["lm_eval_sparse_s50", "lm_eval_sparse_s75"] {
        let mut art = runtime.load(name).unwrap();
        let loss = art.call(&[tokens.clone()]).unwrap()[0].item();
        // Untrained model + moderate sparsity: loss stays in the same band.
        assert!((loss - dense).abs() < 1.0, "{name}: {loss} vs dense {dense}");
    }
}

#[test]
fn trained_params_transfer_between_artifacts() {
    // The partial-conv extension workflow: train dna_train briefly, copy
    // params into dna_eval, and the eval loss must drop vs untrained.
    let runtime = native();
    let mut train = runtime.load("dna_train").unwrap();
    let tspec = train.spec().clone();
    let (batch, seq) = (
        tspec.meta_usize("batch").unwrap(),
        tspec.meta_usize("seq_len").unwrap(),
    );
    let mut gen = flashfftconv::trainer::data::DnaGen::new(64, 21);
    for _ in 0..30 {
        let tokens = gen.batch(batch, seq + 1);
        train.step(&[HostTensor::i32(tokens, &[batch, seq + 1])]).unwrap();
    }
    let mut eval = runtime.load("dna_eval").unwrap();
    let espec = eval.spec().clone();
    let (eb, eseq) = (
        espec.meta_usize("batch").unwrap(),
        espec.meta_usize("seq_len").unwrap(),
    );
    let kmask_len = espec
        .inputs
        .iter()
        .find(|i| i.spec.name == "kmask")
        .map(|i| i.spec.numel())
        .unwrap();
    let mask = HostTensor::f32(vec![1.0; kmask_len], &[kmask_len]);
    let tokens = HostTensor::i32(gen.batch(eb, eseq + 1), &[eb, eseq + 1]);
    let untrained = eval.call(&[tokens.clone(), mask.clone()]).unwrap()[0].item();
    for pname in ["param.embed", "param.filter", "param.proj"] {
        eval.set_operand(pname, &train.state(pname).unwrap()).unwrap();
    }
    let trained = eval.call(&[tokens, mask]).unwrap()[0].item();
    assert!(
        trained < untrained,
        "trained eval loss {trained} should beat untrained {untrained}"
    );
}
