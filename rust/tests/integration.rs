//! Integration tests over real artifacts (require `make artifacts`).
//!
//! Each test loads compiled HLO through the PJRT runtime and checks
//! cross-language behaviour: golden replay, training-state round-trips,
//! loss descent, serving, partial/sparse evaluation. Tests skip (pass
//! trivially with a notice) when the artifact directory is missing so
//! `cargo test` works pre-`make artifacts`.

use flashfftconv::coordinator::partial::{filter_mask, ExtensionPlan};
use flashfftconv::coordinator::router::{ConvKind, Router};
use flashfftconv::coordinator::service::{ConvRequest, ConvService};
use flashfftconv::coordinator::BatchPolicy;
use flashfftconv::runtime::{golden, HostTensor, Runtime};
use flashfftconv::trainer::data::TokenGen;
use flashfftconv::util::Rng;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.txt").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => return,
        }
    };
}

#[test]
fn golden_replay_small_conv() {
    let dir = require_artifacts!();
    let runtime = Runtime::new(&dir).unwrap();
    for name in ["conv_fwd_monarch_n256", "conv_gated_monarch_n1024", "conv_causal_monarch_n512"] {
        let spec = runtime.manifest().get(name).unwrap().clone();
        let g = golden::load(runtime.manifest(), &spec).unwrap().unwrap();
        let mut art = runtime.load(name).unwrap();
        let outs = art.call(&g.inputs).unwrap();
        for (got, want) in outs.iter().zip(&g.outputs) {
            assert!(got.max_abs_diff(want) < 2e-3, "{name}");
        }
    }
}

#[test]
fn monarch_artifact_matches_native_fft_oracle() {
    // Cross-implementation: the compiled kernel vs the pure-Rust FFT conv.
    let dir = require_artifacts!();
    let runtime = Runtime::new(&dir).unwrap();
    let mut art = runtime.load("conv_fwd_monarch_n256").unwrap();
    let (b, h, n) = (2usize, 16usize, 256usize);
    let mut rng = Rng::new(77);
    let u: Vec<f32> = rng.normal_vec(b * h * n);
    let k: Vec<f32> = rng.normal_vec(h * n);
    let outs = art
        .call(&[HostTensor::f32(u.clone(), &[b, h, n]), HostTensor::f32(k.clone(), &[h, n])])
        .unwrap();
    let y = outs[0].as_f32();
    for bi in 0..b {
        for hi in 0..h {
            let urow: Vec<f64> =
                u[(bi * h + hi) * n..(bi * h + hi + 1) * n].iter().map(|&x| x as f64).collect();
            let krow: Vec<f64> = k[hi * n..(hi + 1) * n].iter().map(|&x| x as f64).collect();
            let want = flashfftconv::fft::fft_conv(&urow, &krow);
            let got = &y[(bi * h + hi) * n..(bi * h + hi + 1) * n];
            for (g, w) in got.iter().zip(&want) {
                assert!((*g as f64 - w).abs() < 1e-2, "b={bi} h={hi}");
            }
        }
    }
}

#[test]
fn train_step_state_roundtrip_descends() {
    let dir = require_artifacts!();
    let runtime = Runtime::new(&dir).unwrap();
    let mut art = runtime.load("lm_tiny_train").unwrap();
    let spec = art.spec().clone();
    let batch = spec.meta_usize("batch").unwrap();
    let seq = spec.meta_usize("seq_len").unwrap();
    let vocab = spec.meta_usize("vocab").unwrap();
    let mut gen = TokenGen::new(vocab, 3);
    let mut losses = vec![];
    for _ in 0..12 {
        let tokens = gen.batch(batch, seq + 1);
        let outs = art.step(&[HostTensor::i32(tokens, &[batch, seq + 1])]).unwrap();
        let loss = outs.last().unwrap().item();
        assert!(loss.is_finite(), "loss must stay finite, got {loss}");
        losses.push(loss);
    }
    let head: f64 = losses[..3].iter().sum::<f64>() / 3.0;
    let tail: f64 = losses[losses.len() - 3..].iter().sum::<f64>() / 3.0;
    assert!(tail < head, "loss should descend: {losses:?}");
    // Trained parameters must differ from their initialization.
    let embed = art.state("param.embed").unwrap();
    assert!(embed.as_f32().iter().any(|v| v.abs() > 0.0));
}

#[test]
fn eval_kmask_full_mask_matches_tight_band() {
    let dir = require_artifacts!();
    let runtime = Runtime::new(&dir).unwrap();
    let mut art = runtime.load("lm_eval_kmask").unwrap();
    let spec = art.spec().clone();
    let batch = spec.meta_usize("batch").unwrap();
    let seq = spec.meta_usize("seq_len").unwrap();
    let vocab = spec.meta_usize("vocab").unwrap();
    let mut gen = TokenGen::new(vocab, 4);
    let tokens = HostTensor::i32(gen.batch(batch, seq + 1), &[batch, seq + 1]);
    let full = art
        .call(&[tokens.clone(), HostTensor::f32(filter_mask(seq, seq), &[seq])])
        .unwrap()[0]
        .item();
    // Untrained model: loss near ln(vocab).
    assert!((full - (vocab as f64).ln()).abs() < 0.7, "loss {full}");
    // Truncating the filter changes the loss but keeps it finite/sane.
    let half = art
        .call(&[tokens, HostTensor::f32(filter_mask(seq, seq / 8), &[seq])])
        .unwrap()[0]
        .item();
    assert!(half.is_finite() && (half - full).abs() < 2.0);
}

#[test]
fn service_conv_matches_direct_artifact_call() {
    let dir = require_artifacts!();
    let policy = BatchPolicy { batch_size: 2, max_wait: std::time::Duration::from_millis(2) };
    let service = ConvService::start(&dir, "monarch", policy).unwrap();
    let (h, len) = (16usize, 256usize);
    let mut rng = Rng::new(5);
    let k: Vec<f32> = rng.normal_vec(h * len);
    service.set_filter(ConvKind::Forward, len, k.clone()).unwrap();
    let u: Vec<f32> = rng.normal_vec(h * len);
    let y = service
        .call(ConvRequest { kind: ConvKind::Forward, len, streams: vec![u.clone()] })
        .unwrap();
    assert_eq!(y.len(), h * len);
    // Oracle: native FFT conv per head.
    for hi in 0..h {
        let urow: Vec<f64> = u[hi * len..(hi + 1) * len].iter().map(|&x| x as f64).collect();
        let krow: Vec<f64> = k[hi * len..(hi + 1) * len].iter().map(|&x| x as f64).collect();
        let want = flashfftconv::fft::fft_conv(&urow, &krow);
        for (g, w) in y[hi * len..(hi + 1) * len].iter().zip(&want) {
            assert!((*g as f64 - w).abs() < 1e-2, "head {hi}");
        }
    }
    let s = service.stats();
    assert_eq!(s.requests.load(std::sync::atomic::Ordering::Relaxed), 1);
}

#[test]
fn service_pads_shorter_requests() {
    let dir = require_artifacts!();
    let policy = BatchPolicy { batch_size: 2, max_wait: std::time::Duration::from_millis(1) };
    let service = ConvService::start(&dir, "monarch", policy).unwrap();
    let (h, len) = (16usize, 200usize); // pads to the 256 bucket
    let mut rng = Rng::new(6);
    let u: Vec<f32> = rng.normal_vec(h * len);
    let y = service
        .call(ConvRequest { kind: ConvKind::Causal, len, streams: vec![u.clone()] })
        .unwrap();
    assert_eq!(y.len(), h * len);
    assert!(y.iter().all(|v| v.is_finite()));
}

#[test]
fn router_buckets_match_manifest() {
    let dir = require_artifacts!();
    let runtime = Runtime::new(&dir).unwrap();
    let router = Router::from_manifest(runtime.manifest(), "monarch").unwrap();
    let lens = router.bucket_lens(ConvKind::Forward);
    assert!(lens.contains(&256) && lens.contains(&1024) && lens.contains(&4096));
    let lens_c = router.bucket_lens(ConvKind::Causal);
    assert!(lens_c.contains(&128) && lens_c.contains(&512));
}

#[test]
fn extension_plan_against_dna_eval() {
    let dir = require_artifacts!();
    let runtime = Runtime::new(&dir).unwrap();
    let mut art = runtime.load("dna_eval").unwrap();
    let spec = art.spec().clone();
    let context = spec.meta_usize("seq_len").unwrap();
    let kmask_len = spec
        .inputs
        .iter()
        .find(|i| i.spec.name == "kmask")
        .map(|i| i.spec.numel())
        .unwrap();
    let total = 2 * context;
    let plan = ExtensionPlan::new(total, context, context / 2).unwrap();
    let mut gen = flashfftconv::trainer::data::DnaGen::new(64, 9);
    let seq = gen.sequence(total + 1);
    let mask = vec![1.0f32; kmask_len];
    let mut losses = vec![];
    for w in &plan.windows {
        let window: Vec<i32> = seq[w.start..w.start + context + 1].to_vec();
        let outs = art
            .call(&[
                HostTensor::i32(window, &[1, context + 1]),
                HostTensor::f32(mask.clone(), &[kmask_len]),
            ])
            .unwrap();
        losses.push(outs[0].item());
    }
    let combined = plan.combine_losses(&losses);
    assert!(combined.is_finite() && combined > 0.0 && combined < 3.0);
}

#[test]
fn sparse_eval_artifacts_stay_sane() {
    let dir = require_artifacts!();
    let runtime = Runtime::new(&dir).unwrap();
    let mut base = runtime.load("lm_eval_kmask").unwrap();
    let spec = base.spec().clone();
    let (batch, seq, vocab) = (
        spec.meta_usize("batch").unwrap(),
        spec.meta_usize("seq_len").unwrap(),
        spec.meta_usize("vocab").unwrap(),
    );
    let mut gen = TokenGen::new(vocab, 10);
    let tokens = HostTensor::i32(gen.batch(batch, seq + 1), &[batch, seq + 1]);
    let dense =
        base.call(&[tokens.clone(), HostTensor::f32(vec![1.0; seq], &[seq])]).unwrap()[0].item();
    for name in ["lm_eval_sparse_s50", "lm_eval_sparse_s75"] {
        let mut art = runtime.load(name).unwrap();
        let loss = art.call(&[tokens.clone()]).unwrap()[0].item();
        // Untrained model + moderate sparsity: loss stays in the same band.
        assert!((loss - dense).abs() < 1.0, "{name}: {loss} vs dense {dense}");
    }
}
