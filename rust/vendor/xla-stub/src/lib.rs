//! Offline stub of the `xla` (PJRT) crate.
//!
//! The build environment does not vendor the real XLA runtime, so this
//! crate provides just enough API surface for `flashfftconv`'s `pjrt`
//! feature to *compile*. Every entry point that would touch PJRT returns
//! an error at runtime. On a machine with the real `xla` crate vendored,
//! point the workspace at it with a `[patch]` section and the `pjrt`
//! backend becomes functional without source changes.

use std::fmt;
use std::path::Path;

/// Error type mirroring the real crate's (stringly, `Display`-able).
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn stub_err<T>(what: &str) -> Result<T, Error> {
    Err(Error(format!(
        "{what}: this build links the offline xla stub; vendor the real \
         `xla` crate (see rust/vendor/xla-stub) to use the pjrt backend"
    )))
}

/// Element types used by the runtime.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

/// Host literal (opaque in the stub).
#[derive(Debug)]
pub struct Literal;

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _shape: &[usize],
        _data: &[u8],
    ) -> Result<Literal, Error> {
        stub_err("Literal::create_from_shape_and_untyped_data")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        stub_err("Literal::to_vec")
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        stub_err("Literal::to_tuple")
    }
}

/// Parsed HLO module (opaque in the stub).
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto, Error> {
        stub_err("HloModuleProto::from_text_file")
    }
}

/// XLA computation handle (opaque in the stub).
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer handle (opaque in the stub).
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        stub_err("PjRtBuffer::to_literal_sync")
    }
}

/// Compiled executable handle (opaque in the stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        stub_err("PjRtLoadedExecutable::execute")
    }
}

/// PJRT client handle (opaque in the stub).
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        stub_err("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        stub_err("PjRtClient::compile")
    }
}
