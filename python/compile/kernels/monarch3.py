"""Order-3 Monarch FFT convolution as a fused Pallas kernel (Algorithm 3).

For longer sequences the order-2 factor matrices outgrow fast memory; the
paper's order-3 decomposition adds one matmul on either side of the FFT and
iFFT, shrinking each factor to ``N^(1/3)``.  Structure (forward):

    X : (m1, m2*m3)                    # one packed sequence, reshaped
    A = (F1 @ X) * T_outer             # outer stage + twiddle
    A : (m1, m2, m3)                   # inner order-2 runs per outer row,
    A = (F2 @_axis1 A) * T2            #   batched as plain 2-D matmuls via
    Z = A @_axis2 F3                   #   transpose/reshape (MXU-friendly)

then the packed-domain pointwise multiply and the mirrored inverse chain.
The inner per-row loop of Algorithm 3 is expressed as batched matmuls over
the ``m1`` axis — the same arithmetic, but phrased so the systolic array
sees large 2-D GEMMs instead of ``m1`` small ones (DESIGN.md §2).

Causal (implicit-padding) inputs slice the outer-stage matrices exactly as
in the order-2 kernel.  The r2c packing, coefficient layout, and operand
conventions are shared with :mod:`monarch2` via :mod:`fftmats`.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from . import fftmats
from .monarch2 import Pair, cmatmul, cmul


@dataclasses.dataclass(frozen=True)
class Monarch3Config:
    """Static configuration of one compiled order-3 kernel.

    Same contract as :class:`monarch2.Monarch2Config` but with three Monarch
    factors; only the r2c path is built at order 3 (the complex path exists
    at order 2 for ablations; the paper likewise only ships the optimized
    path at long lengths).
    """

    seq_len: int
    input_len: int
    gated: bool = False
    karatsuba: bool = True
    b_tile: int = 0  # 0 = whole batch per grid cell (paper's B_tile knob)
    h_tile: int = 0  # 0 = all heads per grid cell (paper's H_tile knob)

    def __post_init__(self) -> None:
        if not fftmats.is_pow2(self.seq_len):
            raise ValueError(f"seq_len must be a power of 2, got {self.seq_len}")
        if self.input_len not in (self.seq_len, self.seq_len // 2):
            raise ValueError("input_len must be N (circular) or N/2 (causal)")

    @property
    def causal(self) -> bool:
        return self.input_len == self.seq_len // 2

    @property
    def fft_len(self) -> int:
        return self.seq_len // 2  # r2c path only

    @property
    def factors(self) -> Tuple[int, int, int]:
        return fftmats.monarch_factors(self.fft_len, 3)


def constant_operands(cfg: Monarch3Config) -> "dict[str, np.ndarray]":
    """Constant operands: three DFT factor matrices, two twiddle levels."""
    m1, m2, m3 = cfg.factors
    half = m1 // 2 if cfg.causal else m1
    f1 = fftmats.dft_matrix(m1)
    f1i = fftmats.dft_matrix(m1, inverse=True)
    ops: "dict[str, np.ndarray]" = {}

    def put(name: str, z: np.ndarray) -> None:
        ops[name + "_re"], ops[name + "_im"] = fftmats.split_reim(z)

    put("f1", f1[:, :half])
    put("f2", fftmats.dft_matrix(m2))
    put("f3", fftmats.dft_matrix(m3))
    put("f1inv", f1i[:half, :])
    put("f2inv", fftmats.dft_matrix(m2, inverse=True))
    put("f3inv", fftmats.dft_matrix(m3, inverse=True))
    put("tw1", fftmats.twiddle_grid(m1, m2 * m3))
    put("tw1_inv", fftmats.twiddle_grid(m1, m2 * m3, inverse=True))
    put("tw2", fftmats.twiddle_grid(m2, m3))
    put("tw2_inv", fftmats.twiddle_grid(m2, m3, inverse=True))
    ops["negperm"] = fftmats.neg_freq_perm(cfg.factors)
    return ops


def kernel_operands(cfg: Monarch3Config, k: np.ndarray) -> "dict[str, np.ndarray]":
    """Packed pointwise coefficients in order-3 Monarch layout."""
    k = np.asarray(k, dtype=np.float64)
    if k.shape[-1] < cfg.seq_len:
        pad = cfg.seq_len - k.shape[-1]
        k = np.pad(k, [(0, 0)] * (k.ndim - 1) + [(0, pad)])
    a, b, _ = fftmats.kf_r2c_monarch(k, cfg.factors)
    ops: "dict[str, np.ndarray]" = {}
    ops["ka_re"], ops["ka_im"] = fftmats.split_reim(a)
    ops["kb_re"], ops["kb_im"] = fftmats.split_reim(b)
    return ops


# ---------------------------------------------------------------------------
# Batched complex matmuls over the tile (single large GEMMs; see monarch2)
# ---------------------------------------------------------------------------


def _bcmm_mid(f: Pair, x: Pair, karatsuba: bool) -> Pair:
    """``F @_axis2 X`` for ``X : (S, m1, m2, m3)``, ``F : (k, m2)``."""
    fr, fi = f
    xr, xi = x
    ein = functools.partial(jnp.einsum, "km,samn->sakn",
                            preferred_element_type=jnp.float32)
    if karatsuba:
        t1 = ein(fr, xr)
        t2 = ein(fi, xi)
        t3 = ein(fr + fi, xr + xi)
        return t1 - t2, t3 - t1 - t2
    return ein(fr, xr) - ein(fi, xi), ein(fr, xi) + ein(fi, xr)


def _bcmm_last(x: Pair, f: Pair, karatsuba: bool) -> Pair:
    """``X @_axis3 F`` for ``X : (S, m1, m2, m3)``, ``F : (m3, k)``."""
    xr, xi = x
    s_, m1, m2, m3 = xr.shape
    rr, ri = cmatmul(
        (xr.reshape(s_ * m1 * m2, m3), xi.reshape(s_ * m1 * m2, m3)), f, karatsuba
    )
    k = rr.shape[-1]
    return rr.reshape(s_, m1, m2, k), ri.reshape(s_, m1, m2, k)


def _bcmm_outer(f: Pair, x: Pair, karatsuba: bool) -> Pair:
    """``F @_axis1 X`` for ``X : (S, rows, cols)`` (shared with monarch2)."""
    from .monarch2 import _bcmm_axis1

    return _bcmm_axis1(f, x, karatsuba)


def _kernel_body(cfg: Monarch3Config, refs: List, out_ref) -> None:
    m1, m2, m3 = cfg.factors
    m = m1 * m2 * m3
    half = m1 // 2 if cfg.causal else m1
    it = iter(refs)

    def nxt2() -> Pair:
        r = next(it)[...]
        i = next(it)[...]
        return r, i

    if cfg.gated:
        u = next(it)[...]
        v = next(it)[...]
        w = next(it)[...]
        u = u * w
    else:
        u = next(it)[...]
        v = None
    bt, ht, l = u.shape
    s_ = bt * ht
    ka = nxt2()
    kb = nxt2()
    f1 = nxt2()
    f2 = nxt2()
    f3 = nxt2()
    f1inv = nxt2()
    f2inv = nxt2()
    f3inv = nxt2()
    tw1 = nxt2()
    tw1_inv = nxt2()
    tw2 = nxt2()
    tw2_inv = nxt2()
    negp = next(it)[...]
    kt = cfg.karatsuba

    # Pack re/im planes; causal fills only the top half of the outer rows.
    pairs = u.reshape(s_, half * m2 * m3, 2)
    x = (pairs[..., 0].reshape(s_, half, m2 * m3), pairs[..., 1].reshape(s_, half, m2 * m3))

    # Forward: outer stage then batched inner order-2.
    a = _bcmm_outer(f1, x, kt)
    a = (a[0] * tw1[0][None] - a[1] * tw1[1][None],
         a[0] * tw1[1][None] + a[1] * tw1[0][None])
    a4 = (a[0].reshape(s_, m1, m2, m3), a[1].reshape(s_, m1, m2, m3))
    a4 = _bcmm_mid(f2, a4, kt)
    a4 = (a4[0] * tw2[0][None, None] - a4[1] * tw2[1][None, None],
          a4[0] * tw2[1][None, None] + a4[1] * tw2[0][None, None])
    z = _bcmm_last(a4, f3, kt)
    zr, zi = z[0].reshape(s_, m), z[1].reshape(s_, m)

    # Packed-domain pointwise conv (shared convention with monarch2).
    cr = jnp.take(zr, negp, axis=-1)
    ci = jnp.take(zi, negp, axis=-1)

    def head_bcast(t: jnp.ndarray) -> jnp.ndarray:
        return jnp.broadcast_to(t[None], (bt, ht, m)).reshape(s_, m)

    ar, ai = head_bcast(ka[0]), head_bcast(ka[1])
    br, bi = head_bcast(kb[0]), head_bcast(kb[1])
    yr = ar * zr - ai * zi + br * cr + bi * ci
    yi = ar * zi + ai * zr + bi * cr - br * ci

    # Inverse: batched inner inverse, then outer stage.
    y4 = (yr.reshape(s_, m1, m2, m3), yi.reshape(s_, m1, m2, m3))
    y4 = _bcmm_last(y4, f3inv, kt)
    y4 = (y4[0] * tw2_inv[0][None, None] - y4[1] * tw2_inv[1][None, None],
          y4[0] * tw2_inv[1][None, None] + y4[1] * tw2_inv[0][None, None])
    y4 = _bcmm_mid(f2inv, y4, kt)
    c = (y4[0].reshape(s_, m1, m2 * m3), y4[1].reshape(s_, m1, m2 * m3))
    c = (c[0] * tw1_inv[0][None] - c[1] * tw1_inv[1][None],
         c[0] * tw1_inv[1][None] + c[1] * tw1_inv[0][None])
    out_c = _bcmm_outer(f1inv, c, kt)

    out = jnp.stack([out_c[0], out_c[1]], axis=-1).reshape(bt, ht, l)
    if v is not None:
        out = out * v
    out_ref[...] = out.astype(out_ref.dtype)


def build_conv_fn(cfg: Monarch3Config):
    """Build the jittable fused order-3 conv (same contract as monarch2)."""
    l = cfg.input_len
    n_seq_inputs = 3 if cfg.gated else 1
    filt_shapes = [cfg.fft_len] * 4

    def kernel(*refs) -> None:
        _kernel_body(cfg, list(refs[:-1]), refs[-1])

    const_shapes = [a.shape for a in constant_operands(cfg).values()]

    def conv(u: jnp.ndarray, *ops: jnp.ndarray) -> jnp.ndarray:
        b, h, lin = u.shape
        if lin != l:
            raise ValueError(f"input length {lin} != configured {l}")
        bt = cfg.b_tile or b
        ht = cfg.h_tile or h
        if b % bt or h % ht:
            raise ValueError(f"tile ({bt},{ht}) must divide batch ({b},{h})")
        seq_spec = pl.BlockSpec((bt, ht, l), lambda b_, h_: (b_, h_, 0))
        in_specs = [seq_spec] * n_seq_inputs
        in_specs += [pl.BlockSpec((ht, fs), lambda b_, h_: (h_, 0)) for fs in filt_shapes]
        in_specs += [
            pl.BlockSpec(sh, lambda b_, h_, _nd=len(sh): (0,) * _nd) for sh in const_shapes
        ]
        return pl.pallas_call(
            kernel,
            grid=(b // bt, h // ht),
            in_specs=in_specs,
            out_specs=seq_spec,
            out_shape=jax.ShapeDtypeStruct((b, h, l), u.dtype),
            interpret=True,
        )(u, *ops)

    return conv


def _ops_list(cfg: Monarch3Config, k: np.ndarray) -> List[np.ndarray]:
    return list(kernel_operands(cfg, k).values()) + list(constant_operands(cfg).values())


def conv3_r2c(u, k, *, causal: bool = False, gated_vw=None):
    """Run the order-3 fused conv end to end (test/demo entry point)."""
    n = u.shape[-1] * (2 if causal else 1)
    cfg = Monarch3Config(seq_len=n, input_len=u.shape[-1], gated=gated_vw is not None)
    fn = build_conv_fn(cfg)
    ops = [jnp.asarray(o) for o in _ops_list(cfg, k)]
    if gated_vw is not None:
        v, w = gated_vw
        return fn(jnp.asarray(u), jnp.asarray(v), jnp.asarray(w), *ops)
    return fn(jnp.asarray(u), *ops)
