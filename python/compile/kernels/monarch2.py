"""Order-2 Monarch FFT convolution as fused Pallas kernels (Algorithm 1).

One Pallas grid cell = one (batch, head) sequence, mirroring the paper's
"broadcast the matrix operation across the sequence" layout (Figure 3): the
whole convolution — forward Monarch FFT (two matmuls + twiddle), pointwise
multiply with the pre-computed kernel spectrum, inverse Monarch FFT (two
matmuls + twiddle), plus optional gating — runs inside a single kernel with
every intermediate resident in VMEM.  The HBM<->VMEM schedule is expressed
with ``BlockSpec``s; the permutation between stages is a plain on-chip
reshape/transpose exactly as in Figure 3 (bottom).

Hardware adaptation (DESIGN.md §2): the paper's 16x16x16 WMMA fragments
become MXU-shaped ``jnp.dot``s over the ``N1 x N2`` factor matrices; complex
arithmetic is carried as separate re/im planes through *real* matmuls (the
same trick the paper uses to feed tensor cores), with an optional 3-mult
Karatsuba form.  Kernels run under ``interpret=True`` — CPU PJRT cannot
execute Mosaic custom-calls — so correctness is checked here and TPU
performance is modeled analytically (EXPERIMENTS.md §Perf).

Variants (each maps to a paper experiment):

  * ``conv_basic``          — complex path, circular; the "no domain-specific
                              optimizations" ablation row of Table 3.
  * ``conv_r2c``            — real-to-complex packed path (Appendix A.1):
                              length-N real conv via a length-N/2 complex
                              Monarch FFT.  The default FlashFFTConv.
  * ``conv_r2c_causal``     — implicit zero-padding: input length L, FFT
                              size 2L, half the outermost matmuls skipped.
  * ``conv_r2c_gated[_causal]`` — fused ``y = v * ((u*w) conv k)`` (Table 4).
  * ``conv_sparse``         — frequency-sparse block skipping on the complex
                              path (Appendix A.4, Tables 9/10).

All kernel operands (DFT matrices, twiddles, packed-spectrum coefficients,
the neg-frequency permutation) are *runtime inputs*, not baked constants —
they are exported once by ``aot.py`` as binary fixtures and fed by the Rust
runtime, keeping the HLO text small and letting the coordinator swap kernel
spectra (partial / sparse convolutions) without recompiling.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from . import fftmats

Pair = Tuple[jnp.ndarray, jnp.ndarray]


# ---------------------------------------------------------------------------
# Complex arithmetic on (re, im) pairs — real matmuls only (MXU-friendly)
# ---------------------------------------------------------------------------


def cmatmul(a: Pair, b: Pair, karatsuba: bool = True) -> Pair:
    """Complex matrix multiply via real ``jnp.dot``s.

    ``karatsuba=True`` uses the 3-multiplication form
    ``t1 = ar@br; t2 = ai@bi; t3 = (ar+ai)@(br+bi)`` (L1 perf optimization
    — cuts matmul FLOPs 25% just like the paper's complex-GEMM trick);
    ``False`` uses the plain 4-mult form (kept for the ablation bench).
    """
    ar, ai = a
    br, bi = b
    dot = functools.partial(jnp.dot, preferred_element_type=jnp.float32)
    if karatsuba:
        t1 = dot(ar, br)
        t2 = dot(ai, bi)
        t3 = dot(ar + ai, br + bi)
        return t1 - t2, t3 - t1 - t2
    return dot(ar, br) - dot(ai, bi), dot(ar, bi) + dot(ai, br)


def cmatmul_real_lhs(ar: jnp.ndarray, b: Pair) -> Pair:
    """``(ar + 0i) @ b`` — skips half the work when the lhs is real.

    Used for the first forward stage of the complex path, where the input
    sequence is real (imag plane identically zero).
    """
    br, bi = b
    dot = functools.partial(jnp.dot, preferred_element_type=jnp.float32)
    return dot(ar, br), dot(ar, bi)


def cmul(a: Pair, b: Pair) -> Pair:
    """Elementwise complex multiply on (re, im) pairs."""
    ar, ai = a
    br, bi = b
    return ar * br - ai * bi, ar * bi + ai * br


# ---------------------------------------------------------------------------
# Kernel configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Monarch2Config:
    """Static configuration of one compiled order-2 kernel.

    ``seq_len``   — FFT size N (power of two).
    ``input_len`` — runtime input length; ``seq_len`` for circular convs,
                    ``seq_len // 2`` for causal (implicit-padding) convs.
    ``gated``     — fuse ``y = v * ((u*w) conv k)``.
    ``r2c``       — use the packed real-FFT path (Appendix A.1).
    ``keep_rows/keep_cols`` — frequency-sparsity block (complex path only).
    ``karatsuba`` — 3-mult complex matmuls.
    """

    seq_len: int
    input_len: int
    gated: bool = False
    r2c: bool = True
    keep_rows: int | None = None
    keep_cols: int | None = None
    karatsuba: bool = True
    b_tile: int = 0  # 0 = whole batch per grid cell (paper's B_tile knob)
    h_tile: int = 0  # 0 = all heads per grid cell (paper's H_tile knob)

    def __post_init__(self) -> None:
        if not fftmats.is_pow2(self.seq_len):
            raise ValueError(f"seq_len must be a power of 2, got {self.seq_len}")
        if self.input_len not in (self.seq_len, self.seq_len // 2):
            raise ValueError("input_len must be N (circular) or N/2 (causal)")
        if (self.keep_rows is not None) and self.r2c:
            raise ValueError("frequency-sparse block skipping uses the complex path")

    @property
    def causal(self) -> bool:
        return self.input_len == self.seq_len // 2

    @property
    def fft_len(self) -> int:
        """Length of the complex transform actually computed."""
        return self.seq_len // 2 if self.r2c else self.seq_len

    @property
    def factors(self) -> Tuple[int, int]:
        return fftmats.monarch_factors(self.fft_len, 2)


# ---------------------------------------------------------------------------
# Operand construction (build-time; exported by aot.py as fixtures)
# ---------------------------------------------------------------------------


def constant_operands(cfg: Monarch2Config) -> "dict[str, np.ndarray]":
    """The kernel's constant operands, in call order, as float32/int32.

    For causal convs the first/last-stage DFT matrices are pre-sliced
    (implicit zero-padding: only the non-zero half of the rows of the
    reshaped input participate, and only the first half of the output is
    written back — Section 3.1 "Domain-Specific Optimizations").
    """
    n1, n2 = cfg.factors
    half = n1 // 2 if cfg.causal else n1
    f1 = fftmats.dft_matrix(n1)
    f1i = fftmats.dft_matrix(n1, inverse=True)
    ops: "dict[str, np.ndarray]" = {}

    def put(name: str, z: np.ndarray) -> None:
        ops[name + "_re"], ops[name + "_im"] = fftmats.split_reim(z)

    put("f1", f1[:, :half])       # (n1, half): stage-1 forward, rows sliced
    put("f2", fftmats.dft_matrix(n2))
    put("f1inv", f1i[:half, :])   # (half, n1): last-stage inverse, sliced
    put("f2inv", fftmats.dft_matrix(n2, inverse=True))
    put("tw", fftmats.twiddle_grid(n1, n2))
    put("tw_inv", fftmats.twiddle_grid(n1, n2, inverse=True))
    if cfg.r2c:
        ops["negperm"] = fftmats.neg_freq_perm((n1, n2))
    return ops


def kernel_operands(cfg: Monarch2Config, k: np.ndarray) -> "dict[str, np.ndarray]":
    """Per-filter operands derived from the time-domain kernel ``k (H, L)``.

    r2c path: the packed-domain pointwise coefficients ``A, B`` in Monarch
    layout.  Complex path: the Monarch-layout spectrum itself.
    """
    k = np.asarray(k, dtype=np.float64)
    if k.shape[-1] < cfg.seq_len:
        pad = cfg.seq_len - k.shape[-1]
        k = np.pad(k, [(0, 0)] * (k.ndim - 1) + [(0, pad)])
    elif k.shape[-1] != cfg.seq_len:
        raise ValueError(f"kernel length {k.shape[-1]} > fft size {cfg.seq_len}")
    ops: "dict[str, np.ndarray]" = {}
    if cfg.r2c:
        a, b, _ = fftmats.kf_r2c_monarch(k, cfg.factors)
        ops["ka_re"], ops["ka_im"] = fftmats.split_reim(a)
        ops["kb_re"], ops["kb_im"] = fftmats.split_reim(b)
    else:
        kf = fftmats.kf_monarch(k, cfg.factors)
        if cfg.keep_rows is not None:
            pat = fftmats.SparsityPattern(*cfg.factors, cfg.keep_rows, cfg.keep_cols)
            kf = pat.apply(kf)
            grid = kf.reshape(*kf.shape[:-1], *cfg.factors)
            kf = grid[..., : cfg.keep_rows, : cfg.keep_cols].reshape(*kf.shape[:-1], -1)
        ops["kf_re"], ops["kf_im"] = fftmats.split_reim(kf)
    return ops


# ---------------------------------------------------------------------------
# Kernel bodies
# ---------------------------------------------------------------------------


def _bcmm_axis1(f: Pair, x: Pair, karatsuba: bool) -> Pair:
    """Batched ``F @_axis1 X`` for ``X : (S, rows, cols)`` as ONE large GEMM.

    The S tile sequences are folded into the GEMM's N dimension
    (``(rows_out, rows_in) @ (rows_in, S*cols)``), so the matrix unit sees
    one large multiply instead of S small ones — this is what the paper's
    B_tile/H_tile tiling buys (§3.1 "we also tile the computation across
    the B and H dimensions").
    """
    fr, fi = f
    xr, xi = x
    # dot_general with a free batch dim (einsum) beats an explicit
    # transpose+reshape chain by ~20% on this backend (§Perf log).
    ein = functools.partial(jnp.einsum, "kh,shn->skn",
                            preferred_element_type=jnp.float32)
    if karatsuba:
        t1 = ein(fr, xr)
        t2 = ein(fi, xi)
        t3 = ein(fr + fi, xr + xi)
        return t1 - t2, t3 - t1 - t2
    return ein(fr, xr) - ein(fi, xi), ein(fr, xi) + ein(fi, xr)


def _bcmm_axis2(x: Pair, f: Pair, karatsuba: bool) -> Pair:
    """Batched ``X @_axis2 F``: fold (S, rows) into the GEMM's M dimension."""
    xr, xi = x
    s_, rows, cols = xr.shape
    rr, ri = cmatmul((xr.reshape(s_ * rows, cols), xi.reshape(s_ * rows, cols)), f, karatsuba)
    cols_out = rr.shape[-1]
    return rr.reshape(s_, rows, cols_out), ri.reshape(s_, rows, cols_out)


def _bmul(x: Pair, w: Pair) -> Pair:
    """Elementwise complex multiply with a broadcast (rows, cols) grid."""
    xr, xi = x
    wr, wi = w
    return xr * wr[None] - xi * wi[None], xr * wi[None] + xi * wr[None]


def _r2c_kernel_body(cfg: Monarch2Config, refs: List, out_ref) -> None:
    """Fused r2c conv for one (b_tile, h_tile) grid cell; see module docstring."""
    n1, n2 = cfg.factors
    m = n1 * n2
    half = n1 // 2 if cfg.causal else n1
    it = iter(refs)

    def nxt2() -> Pair:
        r = next(it)[...]
        i = next(it)[...]
        return r, i

    if cfg.gated:
        u = next(it)[...]
        v = next(it)[...]
        w = next(it)[...]
        u = u * w  # pre-gate, fused (Table 4's I/O saving)
    else:
        u = next(it)[...]
        v = None
    bt, ht, l = u.shape
    s_ = bt * ht
    ka = nxt2()  # (ht, m) each plane
    kb = nxt2()
    f1 = nxt2()
    f2 = nxt2()
    f1inv = nxt2()
    f2inv = nxt2()
    tw = nxt2()
    tw_inv = nxt2()
    negp = next(it)[...]
    kt = cfg.karatsuba

    # Pack: z[n] = u[2n] + i*u[2n+1]; causal inputs fill only the top half
    # of each (n1, n2) tile — the rest is implicit zero padding.
    pairs = u.reshape(s_, half * n2, 2)
    x = (pairs[..., 0].reshape(s_, half, n2), pairs[..., 1].reshape(s_, half, n2))

    z = _bcmm_axis1(f1, x, kt)
    z = _bmul(z, tw)
    z = _bcmm_axis2(z, f2, kt)
    zr, zi = z[0].reshape(s_, m), z[1].reshape(s_, m)

    # Packed-domain pointwise conv: Zy = A*Z + B*conj(Z[negperm]); the
    # per-head coefficients broadcast over the b_tile rows.
    cr = jnp.take(zr, negp, axis=-1)
    ci = jnp.take(zi, negp, axis=-1)

    def head_bcast(t: jnp.ndarray) -> jnp.ndarray:
        return jnp.broadcast_to(t[None], (bt, ht, m)).reshape(s_, m)

    ar, ai = head_bcast(ka[0]), head_bcast(ka[1])
    br, bi = head_bcast(kb[0]), head_bcast(kb[1])
    yr = ar * zr - ai * zi + br * cr + bi * ci
    yi = ar * zi + ai * zr + bi * cr - br * ci

    y = (yr.reshape(s_, n1, n2), yi.reshape(s_, n1, n2))
    y = _bcmm_axis2(y, f2inv, kt)
    y = _bmul(y, tw_inv)
    y = _bcmm_axis1(f1inv, y, kt)
    # Unpack: y[2n] = Re, y[2n+1] = Im; causal writes only the first L.
    out = jnp.stack([y[0], y[1]], axis=-1).reshape(bt, ht, l)
    if v is not None:
        out = out * v  # post-gate, fused
    out_ref[...] = out.astype(out_ref.dtype)


def _complex_kernel_body(cfg: Monarch2Config, refs: List, out_ref) -> None:
    """Complex-path conv (ablation + frequency-sparse variant).

    Batched over the (b_tile, h_tile) cell like the r2c body; supports
    causal (implicit-padding) and gated forms so frequency-sparse
    convolutions can drop into model evaluation (Table 9's workload).
    """
    n1, n2 = cfg.factors
    half = n1 // 2 if cfg.causal else n1
    kr = cfg.keep_rows if cfg.keep_rows is not None else n1
    kc = cfg.keep_cols if cfg.keep_cols is not None else n2
    it = iter(refs)

    def nxt2() -> Pair:
        r = next(it)[...]
        i = next(it)[...]
        return r, i

    if cfg.gated:
        u = next(it)[...]
        v = next(it)[...]
        w = next(it)[...]
        u = u * w
    else:
        u = next(it)[...]
        v = None
    bt, ht, l = u.shape
    s_ = bt * ht
    kf = nxt2()  # (ht, kr*kc) planes
    f1 = nxt2()
    f2 = nxt2()
    f1inv = nxt2()
    f2inv = nxt2()
    tw = nxt2()
    tw_inv = nxt2()

    x = u.reshape(s_, half, n2)
    # Forward, with sparse block skipping: rows >= kr / cols >= kc of the
    # spectrum are zeroed by the sparsity pattern, so we never compute them
    # (Appendix A.4): stage 1 keeps kr rows of F1, stage 2 keeps kc cols.
    f1r, f1i = f1
    # Input is real (imag plane identically zero): stage 1 needs only two
    # real batched matmuls instead of a full complex one.
    ein = functools.partial(jnp.einsum, "kh,shn->skn",
                            preferred_element_type=jnp.float32)
    a = (ein(f1r[:kr, :], x), ein(f1i[:kr, :], x))
    twr, twi = tw
    a = _bmul(a, (twr[:kr, :], twi[:kr, :]))
    f2r, f2i = f2
    z = _bcmm_axis2(a, (f2r[:, :kc], f2i[:, :kc]), cfg.karatsuba)

    # Pointwise with the (pre-sliced) Monarch-layout spectrum, per head.
    def head_bcast(t: jnp.ndarray) -> jnp.ndarray:
        return jnp.broadcast_to(t.reshape(1, ht, kr, kc), (bt, ht, kr, kc)).reshape(s_, kr, kc)

    y = (z[0] * head_bcast(kf[0]) - z[1] * head_bcast(kf[1]),
         z[0] * head_bcast(kf[1]) + z[1] * head_bcast(kf[0]))

    # Inverse with the matching slices.
    f2ir, f2ii = f2inv
    c = _bcmm_axis2(y, (f2ir[:kc, :], f2ii[:kc, :]), cfg.karatsuba)
    twir, twii = tw_inv
    c = _bmul(c, (twir[:kr, :], twii[:kr, :]))
    f1ir, f1ii = f1inv
    xr, _ = _bcmm_axis1((f1ir[:, :kr], f1ii[:, :kr]), c, cfg.karatsuba)
    out = xr.reshape(bt, ht, cfg.input_len)
    if v is not None:
        out = out * v
    out_ref[...] = out.astype(out_ref.dtype)


# ---------------------------------------------------------------------------
# pallas_call wrappers
# ---------------------------------------------------------------------------


def _const_specs(cfg: Monarch2Config) -> List[pl.BlockSpec]:
    """BlockSpecs for the constant operands (whole-array, grid-invariant)."""
    shapes = [a.shape for a in constant_operands(cfg).values()]
    return [pl.BlockSpec(s, lambda b, h, _ndim=len(s): (0,) * _ndim) for s in shapes]


def build_conv_fn(cfg: Monarch2Config):
    """Build the jittable fused conv ``fn(u, [v, w,] *filter_ops, *const_ops)``.

    Operand order matches ``kernel_operands`` then ``constant_operands``
    (dict order) — ``aot.py`` records this order in the manifest so the Rust
    runtime can assemble calls without any Python.

    The grid tiles (B, H) by ``cfg.b_tile``/``cfg.h_tile`` (0 = the whole
    dimension in one cell). Each cell convolves its ``b_tile*h_tile``
    sequences through *batched* matmuls — larger GEMMs for the matrix unit
    and, under interpret mode, far fewer grid iterations (§Perf).
    """
    n1, n2 = cfg.factors
    l = cfg.input_len
    n_seq_inputs = 3 if cfg.gated else 1
    if cfg.r2c:
        filt_shapes = [cfg.fft_len] * 4  # ka_re, ka_im, kb_re, kb_im
        body = _r2c_kernel_body
    else:
        kr = cfg.keep_rows if cfg.keep_rows is not None else n1
        kc = cfg.keep_cols if cfg.keep_cols is not None else n2
        filt_shapes = [kr * kc] * 2  # kf_re, kf_im (pre-sliced block)
        body = _complex_kernel_body

    def kernel(*refs) -> None:
        body(cfg, list(refs[:-1]), refs[-1])

    def conv(u: jnp.ndarray, *ops: jnp.ndarray) -> jnp.ndarray:
        b, h, lin = u.shape
        if lin != l:
            raise ValueError(f"input length {lin} != configured {l}")
        bt = cfg.b_tile or b
        ht = cfg.h_tile or h
        if b % bt or h % ht:
            raise ValueError(f"tile ({bt},{ht}) must divide batch ({b},{h})")
        seq_spec = pl.BlockSpec((bt, ht, l), lambda b_, h_: (b_, h_, 0))
        filt_specs = [
            pl.BlockSpec((ht, fs), lambda b_, h_: (h_, 0)) for fs in filt_shapes
        ]
        in_specs = [seq_spec] * n_seq_inputs + filt_specs + _const_specs(cfg)
        return pl.pallas_call(
            kernel,
            grid=(b // bt, h // ht),
            in_specs=in_specs,
            out_specs=seq_spec,
            out_shape=jax.ShapeDtypeStruct((b, h, l), u.dtype),
            interpret=True,
        )(u, *ops)

    return conv


# ---------------------------------------------------------------------------
# Convenience wrappers used by tests and aot.py
# ---------------------------------------------------------------------------


def _ops_list(cfg: Monarch2Config, k: np.ndarray) -> List[np.ndarray]:
    return list(kernel_operands(cfg, k).values()) + list(constant_operands(cfg).values())


def conv_r2c(u, k, *, causal: bool = False, karatsuba: bool = True):
    """Run the packed-real fused conv end to end (test/demo entry point)."""
    n = u.shape[-1] * (2 if causal else 1)
    cfg = Monarch2Config(seq_len=n, input_len=u.shape[-1], karatsuba=karatsuba)
    fn = build_conv_fn(cfg)
    return fn(jnp.asarray(u), *[jnp.asarray(o) for o in _ops_list(cfg, k)])


def conv_r2c_gated(u, v, w, k, *, causal: bool = False):
    """Run the fused gated conv ``v * ((u*w) conv k)`` end to end."""
    n = u.shape[-1] * (2 if causal else 1)
    cfg = Monarch2Config(seq_len=n, input_len=u.shape[-1], gated=True)
    fn = build_conv_fn(cfg)
    return fn(jnp.asarray(u), jnp.asarray(v), jnp.asarray(w),
              *[jnp.asarray(o) for o in _ops_list(cfg, k)])


def conv_basic(u, k, *, karatsuba: bool = True):
    """Complex-path circular conv (the no-domain-opts ablation)."""
    cfg = Monarch2Config(seq_len=u.shape[-1], input_len=u.shape[-1], r2c=False,
                         karatsuba=karatsuba)
    fn = build_conv_fn(cfg)
    return fn(jnp.asarray(u), *[jnp.asarray(o) for o in _ops_list(cfg, k)])


def conv_sparse(u, k, keep_rows: int, keep_cols: int):
    """Frequency-sparse conv: returns (y, sparsified full-order spectrum)."""
    n = u.shape[-1]
    cfg = Monarch2Config(seq_len=n, input_len=n, r2c=False,
                         keep_rows=keep_rows, keep_cols=keep_cols)
    fn = build_conv_fn(cfg)
    y = fn(jnp.asarray(u), *[jnp.asarray(o) for o in _ops_list(cfg, k)])
    # Reference spectrum: sparsify in Monarch layout, map back to DFT order.
    pat = fftmats.SparsityPattern(*cfg.factors, keep_rows, keep_cols)
    kf_mon = pat.apply(fftmats.kf_monarch(np.asarray(k, dtype=np.float64), cfg.factors))
    order = fftmats.monarch_order(cfg.factors)
    kf_full = np.zeros_like(kf_mon)
    kf_full[..., order] = kf_mon
    return y, kf_full
