"""Differentiable FlashFFTConv ops (custom VJP with recomputation).

Pallas kernels have no autodiff rule, and the paper deliberately does not
store forward intermediates anyway — the backward pass *recomputes* them
(§3.1 "Kernel Fusion and Recomputation").  This module packages the fused
kernels as ``jax.custom_vjp`` ops whose backward passes are themselves
Monarch convolutions:

  * ``d/du`` of a causal conv is a causal conv with the *time-reversed*
    kernel (conjugate spectrum) — another fused kernel call;
  * ``d/dk`` is a batched circular correlation, computed spectrally;
  * gated convs recompute the inner convolution for the gate gradient
    instead of storing it (the paper's memory-saving trade).

The filter's packed-domain coefficients are computed *inside* the traced
function with ``jnp.fft`` over the (H, N) filter bank — cheap relative to
the (B, H, N) convolution, and exactly what the paper does for Hyena-style
filters that change every training step.

Only static shapes appear at trace time, so everything here lowers into a
single HLO module via ``aot.py``.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import fftmats, monarch2, monarch3

Coeffs = Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]


def monarch_permute(x: jnp.ndarray, factors: Tuple[int, ...]) -> jnp.ndarray:
    """Apply the Monarch-order permutation ``x[..., order]`` gather-free.

    The layout permutation is a digit reversal, i.e. a chain of
    reshape-transposes (exactly the paper's observation that the Monarch
    permutations "simply become matrix transposes"):

        order[k1*M' + j2] = k1 + n1 * inner_order[j2]
        =>  x.reshape(M', n1).T  then recurse on the last axis.

    Besides being faster than a gather, this sidesteps an XLA-0.5.1 gather
    miscompile observed at some shapes (see aot.py ablation note).
    """
    if len(factors) == 1:
        return x
    n1 = factors[0]
    rest = factors[1:]
    m = int(np.prod(rest))
    batch = x.shape[:-1]
    y = x.reshape(*batch, m, n1)
    y = jnp.swapaxes(y, -1, -2)  # (..., n1, m)
    y = monarch_permute(y, rest)  # inner permutation along the last axis
    return y.reshape(*batch, n1 * m)


def coeffs_from_padded(kpad: jnp.ndarray, factors: Tuple[int, ...]) -> Coeffs:
    """Packed pointwise coefficients (A, B) in Monarch layout, in jnp.

    Differentiable mirror of :func:`fftmats.kf_r2c_monarch`; runs inside the
    traced model so filters generated per-step flow straight to the kernels.
    """
    n = kpad.shape[-1]
    m = n // 2
    kf = jnp.fft.fft(kpad.astype(jnp.float32), axis=-1)
    s = (kf[..., :m] + kf[..., m:]) / 2.0
    d = (kf[..., :m] - kf[..., m:]) / 2.0
    theta = 2.0 * jnp.pi * jnp.arange(m) / n
    a = s - d * jnp.sin(theta)
    b = 1j * d * jnp.cos(theta)
    perm = lambda t: monarch_permute(t.astype(jnp.float32), factors)
    return (perm(jnp.real(a)), perm(jnp.imag(a)), perm(jnp.real(b)), perm(jnp.imag(b)))


def _pad_to(k: jnp.ndarray, n: int) -> jnp.ndarray:
    pad = n - k.shape[-1]
    if pad < 0:
        raise ValueError(f"filter length {k.shape[-1]} exceeds FFT size {n}")
    if pad == 0:
        return k
    return jnp.concatenate([k, jnp.zeros(k.shape[:-1] + (pad,), k.dtype)], axis=-1)


def _flip_padded(kpad: jnp.ndarray) -> jnp.ndarray:
    """Time reversal ``k~[i] = k[(-i) mod N]`` — spectrum becomes conj."""
    return jnp.roll(jnp.flip(kpad, axis=-1), 1, axis=-1)


@functools.lru_cache(maxsize=None)
def _build(seq_len: int, input_len: int, gated: bool, order: int):
    """Build (and cache) the fused kernel + its constant operand list."""
    # NOTE: constants are cached as *numpy* arrays and lifted into each trace
    # on use — caching jnp arrays here would leak tracers across jit scopes.
    if order == 2:
        cfg = monarch2.Monarch2Config(seq_len=seq_len, input_len=input_len, gated=gated)
        fn = monarch2.build_conv_fn(cfg)
        consts = list(monarch2.constant_operands(cfg).values())
    elif order == 3:
        cfg = monarch3.Monarch3Config(seq_len=seq_len, input_len=input_len, gated=gated)
        fn = monarch3.build_conv_fn(cfg)
        consts = list(monarch3.constant_operands(cfg).values())
    else:
        raise ValueError(f"order must be 2 or 3, got {order}")
    return cfg, fn, consts


def default_order(seq_len: int) -> int:
    """Pick the Monarch order for an FFT size, per the §3.2 cost model.

    Order 2 while the factor matrices stay small enough to live in fast
    memory; order 3 beyond that (the paper's p=2 -> p=3 crossover at ~32K).
    """
    return 2 if seq_len <= 32768 else 3


def _run(fn, cfg, consts, *seqs: jnp.ndarray, k: jnp.ndarray) -> jnp.ndarray:
    kpad = _pad_to(k, cfg.seq_len)
    coeffs = coeffs_from_padded(kpad, cfg.factors)
    return fn(*seqs, *coeffs, *consts)


# ---------------------------------------------------------------------------
# Plain causal long conv: y = (u conv k)[:L]
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def long_conv_causal(u: jnp.ndarray, k: jnp.ndarray, order: int = 2) -> jnp.ndarray:
    """Causal long convolution ``y[i] = sum_{j<=i} u[j] k[i-j]``.

    ``u : (B, H, L)``, ``k : (H, Lk)`` with ``Lk <= L`` (a *partial*
    convolution when ``Lk < L`` — §3.3); FFT size ``2L``.
    """
    cfg, fn, consts = _build(2 * u.shape[-1], u.shape[-1], False, order)
    return _run(fn, cfg, consts, u, k=k)


def _long_conv_fwd(u, k, order):
    return long_conv_causal(u, k, order), (u, k)


def _long_conv_bwd(order, res, dy):
    u, k = res
    cfg, fn, consts = _build(2 * u.shape[-1], u.shape[-1], False, order)
    n = cfg.seq_len
    # du: causal conv of dy with the time-reversed kernel (conj spectrum).
    kflip = _flip_padded(_pad_to(k, n))
    coeffs = coeffs_from_padded(kflip, cfg.factors)
    du = fn(dy, *coeffs, *consts)
    # dk: batched circular correlation, spectral (recomputed, not stored).
    dyf = jnp.fft.rfft(_pad_to(dy, n), axis=-1)
    uf = jnp.fft.rfft(_pad_to(u, n), axis=-1)
    dk_full = jnp.fft.irfft(jnp.sum(dyf * jnp.conj(uf), axis=0), n=n, axis=-1)
    dk = dk_full[..., : k.shape[-1]].astype(k.dtype)
    return du.astype(u.dtype), dk


long_conv_causal.defvjp(_long_conv_fwd, _long_conv_bwd)


# ---------------------------------------------------------------------------
# Plain circular conv (FFT size == input size; Tables 3/11/15 workload)
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def long_conv_circular(u: jnp.ndarray, k: jnp.ndarray, order: int = 2) -> jnp.ndarray:
    """Circular convolution with FFT size equal to the input length.

    The paper's "standard" benchmark configuration (Tables 3, 11, 15):
    no causality padding, FFT size N = input size.
    """
    cfg, fn, consts = _build(u.shape[-1], u.shape[-1], False, order)
    return _run(fn, cfg, consts, u, k=k)


def _circ_fwd(u, k, order):
    return long_conv_circular(u, k, order), (u, k)


def _circ_bwd(order, res, dy):
    u, k = res
    cfg, fn, consts = _build(u.shape[-1], u.shape[-1], False, order)
    n = cfg.seq_len
    # du: circular conv with the time-reversed kernel — one more fused call.
    coeffs = coeffs_from_padded(_flip_padded(_pad_to(k, n)), cfg.factors)
    du = fn(dy, *coeffs, *consts)
    dyf = jnp.fft.rfft(dy, axis=-1)
    uf = jnp.fft.rfft(u, axis=-1)
    dk_full = jnp.fft.irfft(jnp.sum(dyf * jnp.conj(uf), axis=0), n=n, axis=-1)
    return du.astype(u.dtype), dk_full[..., : k.shape[-1]].astype(k.dtype)


long_conv_circular.defvjp(_circ_fwd, _circ_bwd)


# ---------------------------------------------------------------------------
# Gated causal conv: y = v * ((u * w) conv k)[:L]  (the Hyena operator)
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def gated_conv_causal(
    u: jnp.ndarray, v: jnp.ndarray, w: jnp.ndarray, k: jnp.ndarray, order: int = 2
) -> jnp.ndarray:
    """Fused gated causal convolution ``y = v * ((u*w) conv k)``.

    Single fused kernel: the gating multiplies never touch HBM (Table 4's
    I/O saving), and nothing but the inputs is saved for backward.
    """
    cfg, fn, consts = _build(2 * u.shape[-1], u.shape[-1], True, order)
    return _run(fn, cfg, consts, u, v, w, k=k)


def _gated_conv_fwd(u, v, w, k, order):
    return gated_conv_causal(u, v, w, k, order), (u, v, w, k)


def _gated_conv_bwd(order, res, dy):
    u, v, w, k = res
    cfg_p, fn_p, consts_p = _build(2 * u.shape[-1], u.shape[-1], False, order)
    n = cfg_p.seq_len
    x = u * w
    kpad = _pad_to(k, n)
    # Recompute the inner convolution for the gate gradient (recomputation
    # instead of storing the forward intermediate — §3.1).
    coeffs_k = coeffs_from_padded(kpad, cfg_p.factors)
    c = fn_p(x, *coeffs_k, *consts_p)
    dv = dy * c
    # Gradient into the conv output, then back through the conv.
    g = dy * v
    coeffs_flip = coeffs_from_padded(_flip_padded(kpad), cfg_p.factors)
    dx = fn_p(g, *coeffs_flip, *consts_p)
    du = dx * w
    dw = dx * u
    # dk spectrally, summed over batch.
    gf = jnp.fft.rfft(_pad_to(g, n), axis=-1)
    xf = jnp.fft.rfft(_pad_to(x, n), axis=-1)
    dk_full = jnp.fft.irfft(jnp.sum(gf * jnp.conj(xf), axis=0), n=n, axis=-1)
    dk = dk_full[..., : k.shape[-1]].astype(k.dtype)
    return du.astype(u.dtype), dv.astype(v.dtype), dw.astype(w.dtype), dk


gated_conv_causal.defvjp(_gated_conv_fwd, _gated_conv_bwd)


# ---------------------------------------------------------------------------
# Frequency-sparse gated causal conv (eval-only; Table 9 workload)
# ---------------------------------------------------------------------------


def kf_mon_sliced(
    kpad: jnp.ndarray, factors: Tuple[int, int], kr: int, kc: int
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Monarch-layout spectrum of ``kpad``, sliced to the kept (kr, kc) block.

    jnp mirror of the build-time path: the Monarch layout is just the
    permuted full FFT, so slicing the layout grid to its kept block both
    sparsifies the spectrum and shrinks the kernel's pointwise operand.
    """
    n1, n2 = factors
    kf = jnp.fft.fft(kpad.astype(jnp.float32), axis=-1)

    def mon_block(plane: jnp.ndarray) -> jnp.ndarray:
        mon = monarch_permute(plane.astype(jnp.float32), factors)
        grid = mon.reshape(*mon.shape[:-1], n1, n2)[..., :kr, :kc]
        return grid.reshape(*mon.shape[:-1], kr * kc).astype(jnp.float32)

    return mon_block(jnp.real(kf)), mon_block(jnp.imag(kf))


@functools.lru_cache(maxsize=None)
def _build_sparse(seq_len: int, input_len: int, gated: bool, kr: int, kc: int):
    cfg = monarch2.Monarch2Config(
        seq_len=seq_len, input_len=input_len, gated=gated, r2c=False,
        keep_rows=kr, keep_cols=kc,
    )
    fn = monarch2.build_conv_fn(cfg)
    consts = list(monarch2.constant_operands(cfg).values())  # numpy; see _build
    return cfg, fn, consts


def sparse_gated_conv_causal(
    u: jnp.ndarray, v: jnp.ndarray, w: jnp.ndarray, k: jnp.ndarray, kr: int, kc: int
) -> jnp.ndarray:
    """Gated causal conv with a frequency-sparsified kernel (inference only).

    ``(kr, kc)`` is the kept block of the (N1, N2) Monarch layout grid; the
    skipped blocks never enter any matmul (Appendix A.4).
    """
    n = 2 * u.shape[-1]
    cfg, fn, consts = _build_sparse(n, u.shape[-1], True, kr, kc)
    kfr, kfi = kf_mon_sliced(_pad_to(k, n), cfg.factors, kr, kc)
    return fn(u, v, w, kfr, kfi, *consts)


def sparse_long_conv_causal(
    u: jnp.ndarray, k: jnp.ndarray, kr: int, kc: int
) -> jnp.ndarray:
    """Plain causal conv with a frequency-sparsified kernel (inference only)."""
    n = 2 * u.shape[-1]
    cfg, fn, consts = _build_sparse(n, u.shape[-1], False, kr, kc)
    kfr, kfi = kf_mon_sliced(_pad_to(k, n), cfg.factors, kr, kc)
    return fn(u, kfr, kfi, *consts)
