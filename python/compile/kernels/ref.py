"""Pure-jnp correctness oracles for the FlashFFTConv kernels.

These implementations define *what the kernels must compute*.  They are used

  * by pytest (every Pallas kernel is asserted allclose against them, with
    hypothesis sweeping shapes and dtypes),
  * as the "PyTorch FFT conv" baseline artifact (``fft_conv`` /
    ``fft_conv_gated`` lowered to HLO: the standard unfused full-complex
    ``ifft(fft(u) * kf)`` pipeline the paper benchmarks against), and
  * as the differentiable reference for gradient checks of the custom VJP.

Shapes follow the paper: ``u : (B, H, N)``, kernel ``k : (H, N)`` broadcast
over the batch dimension; gating inputs ``v, w`` match ``u``.
"""

from __future__ import annotations

import jax.numpy as jnp


def direct_conv(u: jnp.ndarray, k: jnp.ndarray) -> jnp.ndarray:
    """Circular convolution by the definition (O(N^2)); small-N oracle.

    ``y[..., i] = sum_j u[..., j] * k[..., (i - j) mod N]``.
    """
    n = u.shape[-1]
    idx = (jnp.arange(n)[:, None] - jnp.arange(n)[None, :]) % n
    circ = k[..., idx]  # (H, N_out, N_in): circulant built from each filter
    return jnp.einsum("hij,...hj->...hi", circ, u)


def direct_causal_conv(u: jnp.ndarray, k: jnp.ndarray) -> jnp.ndarray:
    """Causal (linear) convolution truncated to the input length; oracle.

    ``y[i] = sum_{j<=i} u[j] * k[i - j]`` — what zero-padding the circular
    convolution to ``2N`` computes (Section 2.1 of the paper).
    """
    n = u.shape[-1]
    up = jnp.concatenate([u, jnp.zeros_like(u)], axis=-1)
    kp = jnp.concatenate([k, jnp.zeros_like(k)], axis=-1)
    return direct_conv(up, kp)[..., :n]


def fft_conv(u: jnp.ndarray, k: jnp.ndarray) -> jnp.ndarray:
    """Standard FFT convolution, Eq. (1) of the paper: the baseline.

    Full complex FFT over the (circular) sequence — the structure of the
    PyTorch baseline the paper benchmarks against: unfused FFT, pointwise
    multiply in frequency domain, inverse FFT, take the real part.
    """
    uf = jnp.fft.fft(u.astype(jnp.float32), axis=-1)
    kf = jnp.fft.fft(k.astype(jnp.float32), axis=-1)
    return jnp.real(jnp.fft.ifft(uf * kf, axis=-1)).astype(u.dtype)


def fft_conv_causal(u: jnp.ndarray, k: jnp.ndarray) -> jnp.ndarray:
    """Causal FFT convolution: zero-pad to 2N, convolve, truncate."""
    n = u.shape[-1]
    up = jnp.concatenate([u, jnp.zeros_like(u)], axis=-1)
    kp = jnp.concatenate([k, jnp.zeros_like(k)], axis=-1)
    return fft_conv(up, kp)[..., :n]


def fft_conv_gated(
    u: jnp.ndarray, v: jnp.ndarray, w: jnp.ndarray, k: jnp.ndarray
) -> jnp.ndarray:
    """Gated convolution ``y = v * ((u * w) conv k)`` (Table 4 workload)."""
    return v * fft_conv(u * w, k)


def fft_conv_gated_causal(
    u: jnp.ndarray, v: jnp.ndarray, w: jnp.ndarray, k: jnp.ndarray
) -> jnp.ndarray:
    """Causal gated convolution (the form used inside Hyena blocks)."""
    return v * fft_conv_causal(u * w, k)


def fft_conv_kf(u: jnp.ndarray, kf: jnp.ndarray) -> jnp.ndarray:
    """Circular convolution against a pre-computed full spectrum ``kf``.

    Used by frequency-sparse tests, where ``kf`` has been block-zeroed and
    no longer corresponds to a real time-domain kernel's exact spectrum.
    """
    uf = jnp.fft.fft(u.astype(jnp.complex64), axis=-1)
    return jnp.real(jnp.fft.ifft(uf * kf, axis=-1))
