"""Analytic TPU roofline estimates for the Monarch kernels (§Perf, L1).

Interpret-mode wall-clock is a CPU artifact, so real-accelerator behaviour
is estimated structurally, per shipped kernel configuration:

  * **VMEM footprint** of one grid cell — every buffer the fused kernel
    holds at once (input tile, re/im working planes, coefficient rows,
    constant matrices). Must fit the ~16 MB/core VMEM budget for the fusion
    story to hold; this is the analogue of the paper's SRAM bound (§3.1).
  * **MXU utilization estimate** — the fraction of peak systolic-array
    throughput the kernel's GEMM shapes can sustain, modeled as the product
    of dimension-fill factors against the 128x128 MXU (a GEMM with K=32
    fills 25% of the contraction dimension, etc.), weighted by FLOP share.
  * **Arithmetic intensity** (FLOPs per HBM byte) — decides memory- vs
    compute-bound per the §3.2 cost model.

Run directly (``python -m compile.kernels.roofline``) to print the table
recorded in EXPERIMENTS.md §Perf; unit-tested in ``test_roofline.py``.
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

from . import fftmats

MXU_DIM = 128                 # TPU systolic array dimension
VMEM_BYTES = 16 * 1024 * 1024  # per-core VMEM
DTYPE_BYTES = 4                # f32 planes (bf16 would halve this)


@dataclasses.dataclass(frozen=True)
class GemmShape:
    """One batched GEMM executed by the kernel: (m, k, n) x count."""

    m: int
    k: int
    n: int
    count: int = 1

    @property
    def flops(self) -> float:
        return 2.0 * self.m * self.k * self.n * self.count

    @property
    def mxu_fill(self) -> float:
        """Fraction of the MXU the shape can keep busy.

        The systolic array is MXU_DIM x MXU_DIM with the contraction
        streaming through: fill = min(1, m/MXU) * min(1, n/MXU); short k
        additionally costs pipeline drain, modeled as k/(k+MXU).
        """
        fill_m = min(1.0, self.m / MXU_DIM)
        fill_n = min(1.0, self.n / MXU_DIM)
        drain = self.k / (self.k + MXU_DIM)
        return fill_m * fill_n * drain


@dataclasses.dataclass(frozen=True)
class KernelEstimate:
    name: str
    seq_len: int
    tile_seqs: int
    vmem_bytes: int
    mxu_utilization: float
    arithmetic_intensity: float
    gemms: Tuple[GemmShape, ...]

    @property
    def fits_vmem(self) -> bool:
        return self.vmem_bytes <= VMEM_BYTES


def order2_estimate(seq_len: int, tile_seqs: int, gated: bool = False,
                    causal: bool = False) -> KernelEstimate:
    """Estimate for the order-2 r2c kernel at one (N, tile) configuration."""
    m = seq_len // 2  # packed transform length
    n1, n2 = fftmats.monarch_factors(m, 2)
    half = n1 // 2 if causal else n1
    s = tile_seqs

    # GEMMs per direction: stage1 (n1 x half) @ (half x s*n2) and
    # stage2 (s*n1 x n2) @ (n2 x n2); karatsuba = 3 real GEMMs each.
    gemms = (
        GemmShape(n1, half, s * n2, 3),        # forward stage 1
        GemmShape(s * n1, n2, n2, 3),          # forward stage 2
        GemmShape(s * n1, n2, n2, 3),          # inverse stage 1
        GemmShape(half, n1, s * n2, 3),        # inverse stage 2
    )
    flops = sum(g.flops for g in gemms)
    util = sum(g.mxu_fill * g.flops for g in gemms) / flops

    # VMEM: input tile (+2 gate tiles), two working plane pairs over the
    # packed length, per-head coefficient rows, constant matrices+twiddles.
    seq_tiles = (3 if gated else 1) * s * seq_len
    planes = 2 * 2 * s * m           # two live (re, im) pairs
    coeffs = 4 * s * m               # ka/kb rows for the tile's heads
    consts = 2 * (n1 * half + n2 * n2 + n1 * n1 + 2 * n1 * n2) + m
    vmem = DTYPE_BYTES * (seq_tiles + planes + coeffs + consts)

    # HBM traffic: tile in/out + coefficients + constants, once per cell.
    hbm = DTYPE_BYTES * ((2 if not gated else 4) * s * seq_len + 4 * m + consts)
    # Pointwise work excluded from utilization (runs on the VPU).
    return KernelEstimate(
        name=f"order2{'_gated' if gated else ''}{'_causal' if causal else ''}",
        seq_len=seq_len,
        tile_seqs=s,
        vmem_bytes=vmem,
        mxu_utilization=util,
        arithmetic_intensity=flops / hbm,
        gemms=gemms,
    )


def order3_estimate(seq_len: int, tile_seqs: int) -> KernelEstimate:
    """Estimate for the order-3 r2c kernel."""
    m = seq_len // 2
    m1, m2, m3 = fftmats.monarch_factors(m, 3)
    s = tile_seqs
    gemms = (
        GemmShape(m1, m1, s * m2 * m3, 3),
        GemmShape(m2, m2, s * m1 * m3, 3),
        GemmShape(s * m1 * m2, m3, m3, 3),
        GemmShape(s * m1 * m2, m3, m3, 3),
        GemmShape(m2, m2, s * m1 * m3, 3),
        GemmShape(m1, m1, s * m2 * m3, 3),
    )
    flops = sum(g.flops for g in gemms)
    util = sum(g.mxu_fill * g.flops for g in gemms) / flops
    planes = 2 * 2 * s * m
    consts = 2 * (m1 * m1 * 2 + m2 * m2 * 2 + m3 * m3 * 2 + m1 * m2 * m3 + m2 * m3) + m
    vmem = DTYPE_BYTES * (s * seq_len + planes + 4 * s * m + consts)
    hbm = DTYPE_BYTES * (2 * s * seq_len + 4 * m + consts)
    return KernelEstimate(
        name="order3",
        seq_len=seq_len,
        tile_seqs=s,
        vmem_bytes=vmem,
        mxu_utilization=util,
        arithmetic_intensity=flops / hbm,
        gemms=gemms,
    )


def max_tile_for_vmem(seq_len: int, order: int = 2) -> int:
    """Largest power-of-two tile (sequences/cell) that fits VMEM."""
    est = order2_estimate if order == 2 else order3_estimate
    s = 1
    while 2 * s * seq_len * DTYPE_BYTES < VMEM_BYTES:
        if not est(seq_len, 2 * s).fits_vmem:
            break
        s *= 2
    return s


def shipped_configs() -> List[KernelEstimate]:
    """Estimates for the artifact set `aot.py` ships.

    Tiles follow the VMEM budget: B*H = 32 sequences per cell while that
    fits (the CPU bench shape), shrinking at long lengths exactly as the
    paper's B_tile/H_tile would on an accelerator.
    """
    out = []
    for n in (256, 1024, 4096, 16384):
        tile = min(32, max_tile_for_vmem(n, 2))
        out.append(order2_estimate(n, tile))
        out.append(order2_estimate(n, tile, gated=True))
    out.append(order3_estimate(65536, min(32, max_tile_for_vmem(65536, 3))))
    return out


def report() -> str:
    lines = [
        f"{'kernel':<22}{'N':>8}{'tile':>6}{'VMEM_MB':>9}{'fits':>6}"
        f"{'MXU_util':>10}{'AI(F/B)':>9}"
    ]
    for e in shipped_configs():
        lines.append(
            f"{e.name:<22}{e.seq_len:>8}{e.tile_seqs:>6}"
            f"{e.vmem_bytes / 1e6:>9.2f}{str(e.fits_vmem):>6}"
            f"{e.mxu_utilization:>10.2f}{e.arithmetic_intensity:>9.1f}"
        )
    lines.append("")
    lines.append("max tile sizes under the 16MB VMEM budget:")
    for n in (4096, 16384, 65536, 262144):
        order = 2 if n <= 65536 else 3
        lines.append(f"  N={n:<8} order-{order}: {max_tile_for_vmem(n, order)} seqs/cell")
    return "\n".join(lines)


if __name__ == "__main__":
    print(report())
