"""Build-time FFT matrix / twiddle / permutation machinery for FlashFFTConv.

Everything in this module runs ONCE, at artifact-build time (and in tests).
It produces the constant operands that the Pallas kernels consume:

  * DFT / inverse-DFT matrices for each Monarch factor,
  * twiddle-factor grids (the diagonal ``D`` of the Monarch decomposition,
    laid out as the 2-D grid Algorithm 1 multiplies elementwise),
  * the *Monarch order* permutation — the digit-permuted output order the
    decomposed transform naturally produces (Section 3.1 of the paper; we
    never undo it, we bake it into the pre-computed ``k_f`` instead),
  * real-to-complex packing coefficients (Appendix A.1: a length-``N`` real
    FFT via a length-``N/2`` complex FFT),
  * frequency-sparsity block patterns (Appendix A.4 / Table 10).

All spectra live in float32 re/im pairs so the Pallas kernels only ever see
real matrices — mirroring how the paper feeds complex data through real
tensor-core GEMMs.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence, Tuple

import numpy as np

# ---------------------------------------------------------------------------
# Factorization helpers
# ---------------------------------------------------------------------------


def is_pow2(n: int) -> bool:
    """True iff ``n`` is a positive power of two."""
    return n > 0 and (n & (n - 1)) == 0


def monarch_factors(n: int, order: int) -> Tuple[int, ...]:
    """Split power-of-two ``n`` into ``order`` balanced power-of-two factors.

    Mirrors the paper's choice of near-square factors (so the matrices feed
    the matrix unit efficiently): the log2 budget is distributed as evenly
    as possible, larger factors first, e.g. ``monarch_factors(8192, 2) ==
    (128, 64)`` and ``monarch_factors(4096, 3) == (16, 16, 16)``.
    """
    if not is_pow2(n):
        raise ValueError(f"sequence length must be a power of two, got {n}")
    if order < 1:
        raise ValueError(f"order must be >= 1, got {order}")
    logn = n.bit_length() - 1
    if order > logn and n > 1:
        raise ValueError(f"cannot split N={n} into {order} factors > 1")
    base, extra = divmod(logn, order)
    logs = [base + (1 if i < extra else 0) for i in range(order)]
    return tuple(1 << l for l in logs)


# ---------------------------------------------------------------------------
# DFT matrices and twiddles
# ---------------------------------------------------------------------------


def dft_matrix(n: int, inverse: bool = False) -> np.ndarray:
    """Dense ``n x n`` DFT matrix (complex128 at build time).

    ``inverse=True`` returns the unitary-up-to-1/n inverse (includes the
    ``1/n`` normalization, so ``dft_matrix(n, True) @ dft_matrix(n) == I``).
    """
    k = np.arange(n)
    sign = 2j if inverse else -2j
    mat = np.exp(sign * np.pi * np.outer(k, k) / n)
    if inverse:
        mat /= n
    return mat


def twiddle_grid(n1: int, n2: int, inverse: bool = False) -> np.ndarray:
    """Twiddle grid ``T[k1, n2] = exp(-+ 2*pi*i * k1 * n2 / (n1*n2))``.

    This is the diagonal ``D`` of the order-2 Monarch decomposition, laid
    out as the ``n1 x n2`` grid Algorithm 1 multiplies elementwise between
    the two matmul stages.
    """
    n = n1 * n2
    k1 = np.arange(n1)[:, None]
    j2 = np.arange(n2)[None, :]
    sign = 2j if inverse else -2j
    return np.exp(sign * np.pi * k1 * j2 / n)


# ---------------------------------------------------------------------------
# Monarch-order reference transform + permutation bookkeeping
# ---------------------------------------------------------------------------


def monarch_fft_ref(x: np.ndarray, factors: Sequence[int]) -> np.ndarray:
    """Reference Monarch-decomposed FFT (recursive; defines *the* layout).

    Computes ``P @ FFT(x)`` where ``P`` is the digit permutation the
    decomposition naturally produces.  Every kernel, and the pre-computed
    ``k_f``, uses exactly this layout; the permutation cancels inside the
    convolution (conv theorem is permutation-invariant) so it is never
    materialized at runtime.

    Order-2 identity (validated in tests): for ``x`` reshaped row-major to
    ``(N1, N2)``, ``B = ((F_N1 @ X) * T) @ F_N2`` satisfies
    ``B[k1, k2] == FFT(x)[k1 + N1*k2]``.
    """
    x = np.asarray(x, dtype=np.complex128)
    factors = tuple(int(f) for f in factors)
    n = int(np.prod(factors))
    if x.shape[-1] != n:
        raise ValueError(f"input length {x.shape[-1]} != prod(factors) {n}")
    if len(factors) == 1:
        return x @ dft_matrix(n).T  # plain DFT, identity permutation
    n1, rest = factors[0], factors[1:]
    m = n // n1
    batch = x.shape[:-1]
    mat = x.reshape(*batch, n1, m)
    a = np.einsum("kn,...nm->...km", dft_matrix(n1), mat)
    a = a * twiddle_grid(n1, m)
    inner = monarch_fft_ref(a, rest)  # inner transform along last axis, per row
    return inner.reshape(*batch, n)


def monarch_ifft_ref(y: np.ndarray, factors: Sequence[int]) -> np.ndarray:
    """Inverse of :func:`monarch_fft_ref` (undoes layout and transform)."""
    y = np.asarray(y, dtype=np.complex128)
    factors = tuple(int(f) for f in factors)
    n = int(np.prod(factors))
    if len(factors) == 1:
        return y @ dft_matrix(n, inverse=True).T
    n1, rest = factors[0], factors[1:]
    m = n // n1
    batch = y.shape[:-1]
    mat = y.reshape(*batch, n1, m)
    a = monarch_ifft_ref(mat, rest)
    a = a * twiddle_grid(n1, m, inverse=True)
    x = np.einsum("kn,...nm->...km", dft_matrix(n1, inverse=True), a)
    return x.reshape(*batch, n)


def monarch_order(factors: Sequence[int]) -> np.ndarray:
    """``order[j]`` = true DFT frequency stored at Monarch-layout slot ``j``.

    Recursive closed form derived from the order-2 identity:
    ``order[k1*M + j2] = k1 + N1 * inner_order[j2]``.
    """
    factors = tuple(int(f) for f in factors)
    n = int(np.prod(factors))
    if len(factors) == 1:
        return np.arange(n, dtype=np.int64)
    n1, rest = factors[0], factors[1:]
    m = n // n1
    inner = monarch_order(rest)
    k1 = np.arange(n1)[:, None]
    return (k1 + n1 * inner[None, :]).reshape(n)


def neg_freq_perm(factors: Sequence[int]) -> np.ndarray:
    """Permutation ``r`` with ``layout_freq(r[j]) == (-layout_freq(j)) mod M``.

    Used by the r2c packing: the ``Z[k] <-> conj(Z[M-k])`` pairing of
    Appendix A.1, expressed directly in Monarch layout.
    """
    order = monarch_order(factors)
    m = order.shape[0]
    inv = np.empty(m, dtype=np.int64)
    inv[order] = np.arange(m)
    return inv[(-order) % m].astype(np.int32)


# ---------------------------------------------------------------------------
# Real-to-complex packing (Appendix A.1)
# ---------------------------------------------------------------------------


def r2c_pointwise_coeffs(kf: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Packed-domain pointwise coefficients ``(A, B)`` for a real conv.

    Given the full length-``N`` spectrum ``kf`` of a *real* kernel, returns
    length-``M = N/2`` complex coefficient arrays such that the circular
    convolution ``y = ifft(fft(u) * kf)`` of a real ``u`` equals unpacking

        Z_y[k] = A[k] * Z[k] + B[k] * conj(Z[(M-k) mod M])

    where ``Z = fft_M(u[0::2] + 1j*u[1::2])`` and ``y[0::2], y[1::2] =
    Re, Im of ifft_M(Z_y)``.  Derivation (from the even/odd split of both
    the analysis and synthesis sides of Appendix A.1):

        s[k] = (kf[k] + kf[k+M]) / 2,   d[k] = (kf[k] - kf[k+M]) / 2
        A[k] = s[k] - d[k] * sin(2*pi*k/N)
        B[k] = 1j * d[k] * cos(2*pi*k/N)

    Validated against the direct spectrum path in tests.
    """
    kf = np.asarray(kf, dtype=np.complex128)
    n = kf.shape[-1]
    if n % 2 != 0:
        raise ValueError("r2c packing needs even N")
    m = n // 2
    s = (kf[..., :m] + kf[..., m:]) / 2.0
    d = (kf[..., :m] - kf[..., m:]) / 2.0
    theta = 2.0 * np.pi * np.arange(m) / n
    a = s - d * np.sin(theta)
    b = 1j * d * np.cos(theta)
    return a, b


# ---------------------------------------------------------------------------
# Frequency-sparsity patterns (Appendix A.4 / Table 10)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SparsityPattern:
    """Block-sparsity pattern for ``k_f`` in Monarch layout (order-2 view).

    Zero out layout rows ``>= keep_rows`` and layout columns ``>= keep_cols``
    of ``k_f`` reshaped to ``(N1, N2)``.  The kernels then *skip* the
    corresponding slices of every matmul (forward stage 1 keeps ``keep_rows``
    rows of ``F1``; stage 2 keeps ``keep_cols`` columns of ``F2``; the
    inverse stages shrink symmetrically) — the Appendix A.4 mechanism.
    """

    n1: int
    n2: int
    keep_rows: int
    keep_cols: int

    def __post_init__(self) -> None:
        if not (1 <= self.keep_rows <= self.n1):
            raise ValueError(f"keep_rows {self.keep_rows} not in [1, {self.n1}]")
        if not (1 <= self.keep_cols <= self.n2):
            raise ValueError(f"keep_cols {self.keep_cols} not in [1, {self.n2}]")

    @property
    def sparsity_fraction(self) -> float:
        """Fraction of ``k_f`` entries zeroed (Table 10's ``S``)."""
        return 1.0 - (self.keep_rows * self.keep_cols) / (self.n1 * self.n2)

    @property
    def matmul_flop_fraction(self) -> float:
        """Remaining fraction of Monarch matmul FLOPs after skipping.

        Dense cost per sequence: ``2 * (N*N1 + N*N2)`` complex MACs (two
        stages each way).  Sparse: stage-1 fwd scales by rows kept, stage-2
        fwd by cols kept applied to full rows... computed exactly below and
        used by the Table 9 speedup model.
        """
        r, c = self.keep_rows, self.keep_cols
        n1, n2 = self.n1, self.n2
        dense = 2 * (n1 * n1 * n2 + n1 * n2 * n2)  # fwd + inv, both stages
        # fwd stage 1: (r x n1) @ (n1 x n2) ; fwd stage 2: (r x n2) @ (n2 x c)
        # inv stage 1: (r x c) @ (c x n2)  ; inv stage 2: (n1 x r) @ (r x n2)
        sparse = (r * n1 * n2) + (r * n2 * c) + (r * c * n2) + (n1 * r * n2)
        return sparse / dense

    def apply(self, kf_mon: np.ndarray) -> np.ndarray:
        """Zero the pattern out of a Monarch-layout spectrum ``(..., N)``."""
        n = self.n1 * self.n2
        if kf_mon.shape[-1] != n:
            raise ValueError(f"kf length {kf_mon.shape[-1]} != N1*N2 = {n}")
        grid = kf_mon.reshape(*kf_mon.shape[:-1], self.n1, self.n2).copy()
        grid[..., self.keep_rows :, :] = 0
        grid[..., :, self.keep_cols :] = 0
        return grid.reshape(*kf_mon.shape[:-1], n)


def table10_patterns(n1: int, n2: int) -> "dict[str, SparsityPattern]":
    """The Table 10 sparsity ladder, rescaled to an (n1, n2) order-2 grid.

    The paper's 4-way ladder zeroes {0, 1/2, 3/4, ...} of successive digit
    dimensions; in the order-2 view that corresponds to halving rows, then
    halving columns, then quartering again — reproducing the same sparsity
    fractions S = {0, .5, .75, ~.79, ~.84, ~.91}.
    """
    return {
        "s0": SparsityPattern(n1, n2, n1, n2),
        "s50": SparsityPattern(n1, n2, n1 // 2, n2),
        "s75": SparsityPattern(n1, n2, n1 // 2, n2 // 2),
        "s84": SparsityPattern(n1, n2, n1 // 4, n2 * 5 // 8),
        "s91": SparsityPattern(n1, n2, n1 // 4, n2 * 3 // 8),
        "s94": SparsityPattern(n1, n2, n1 // 4, n2 // 4),
    }


# ---------------------------------------------------------------------------
# Kernel operand bundles (what aot.py feeds the Pallas kernels)
# ---------------------------------------------------------------------------


def split_reim(z: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Complex -> (re, im) float32 pair."""
    z = np.asarray(z)
    return z.real.astype(np.float32), z.imag.astype(np.float32)


@dataclasses.dataclass(frozen=True)
class Monarch2Operands:
    """All constant operands of the order-2 fused kernel, as float32 re/im."""

    n1: int
    n2: int
    f1: Tuple[np.ndarray, np.ndarray]
    f2: Tuple[np.ndarray, np.ndarray]
    f1_inv: Tuple[np.ndarray, np.ndarray]
    f2_inv: Tuple[np.ndarray, np.ndarray]
    tw: Tuple[np.ndarray, np.ndarray]
    tw_inv: Tuple[np.ndarray, np.ndarray]


def monarch2_operands(n: int) -> Monarch2Operands:
    """Build the constant operand bundle for a length-``n`` order-2 kernel."""
    n1, n2 = monarch_factors(n, 2)
    return Monarch2Operands(
        n1=n1,
        n2=n2,
        f1=split_reim(dft_matrix(n1)),
        f2=split_reim(dft_matrix(n2)),
        f1_inv=split_reim(dft_matrix(n1, inverse=True)),
        f2_inv=split_reim(dft_matrix(n2, inverse=True)),
        tw=split_reim(twiddle_grid(n1, n2)),
        tw_inv=split_reim(twiddle_grid(n1, n2, inverse=True)),
    )


def kf_monarch(k: np.ndarray, factors: Sequence[int]) -> np.ndarray:
    """Pre-compute a real kernel's spectrum in Monarch layout.

    ``k`` is the (``H x N`` or ``N``) time-domain filter; returns complex128
    ``P @ FFT(k)`` matching the layout the fused kernels produce internally.
    """
    return monarch_fft_ref(np.asarray(k, dtype=np.complex128), factors)


def kf_r2c_monarch(
    k: np.ndarray, factors_half: Sequence[int]
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Packed-domain coefficients ``(A_mon, B_mon, negperm)`` for real convs.

    ``factors_half`` factorizes ``M = N/2``; coefficients are returned in the
    Monarch layout of the half-length transform, with the index pairing
    permutation ``negperm`` baked for the same layout.
    """
    k = np.asarray(k, dtype=np.complex128)
    kf = np.fft.fft(k, axis=-1)
    a, b = r2c_pointwise_coeffs(kf)
    order = monarch_order(factors_half)
    return a[..., order], b[..., order], neg_freq_perm(factors_half)
