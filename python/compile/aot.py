"""AOT artifact builder: lower every model/kernel to HLO text + manifest.

This is the single entry point of the Python compile path (``make
artifacts``).  It produces, under ``artifacts/``:

  * ``<name>.hlo.txt``   — HLO text for each artifact (the interchange
    format: jax >= 0.5 serialized protos use 64-bit ids that the runtime's
    XLA rejects, but HLO text round-trips cleanly — see
    /opt/xla-example/README.md);
  * ``<name>.fix.bin``   — fixture payload: constant operands (DFT factor
    matrices, twiddles, permutations) and initial state (model parameters,
    optimizer moments) as raw little-endian arrays;
  * ``<name>.golden.bin``— optional golden transcript (example runtime
    inputs followed by expected outputs) for Rust integration tests;
  * ``manifest.txt``     — the line-based index the Rust runtime parses
    (see ``rust/src/util/manifest.rs`` for the grammar).

Input kinds in the manifest:

  * ``runtime`` — supplied by the Rust caller on every execution;
  * ``const``   — loaded once from the fixture file (never changes);
  * ``state``   — initialized from the fixture, then fed back from the
    previous call's outputs (training state); the first ``n_state``
    outputs of such artifacts are the next-step values of the first
    ``n_state`` inputs, in order.

Artifact groups map one-to-one onto the paper's experiments (DESIGN.md §5);
select subsets with ``--groups`` for faster incremental builds.
"""

from __future__ import annotations

import argparse
import os
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import conv_op, fftmats, monarch2, monarch3, ref

_DTYPE_NAMES = {np.dtype(np.float32): "f32", np.dtype(np.int32): "i32"}


def _dtype_name(dt) -> str:
    return _DTYPE_NAMES[np.dtype(dt)]


def _shape_str(shape: Tuple[int, ...]) -> str:
    return ",".join(str(d) for d in shape) if shape else "-"


class InputSpec:
    """One artifact input: name, example/initial value, and kind."""

    def __init__(self, name: str, value: np.ndarray, kind: str) -> None:
        assert kind in ("runtime", "const", "state"), kind
        self.name = name
        self.value = np.ascontiguousarray(value)
        self.kind = kind


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe bridge).

    CRITICAL: print with ``print_large_constants=True``. The default
    printer elides big literals as ``constant({...})``, which the runtime's
    older HLO parser accepts *silently* and mis-materializes — every traced
    constant (positional features, twiddle factors, decay windows) would be
    garbage at execution time.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # Newer metadata attributes (source_end_line, ...) are rejected by the
    # runtime's older HLO parser; metadata is debug-only, drop it.
    opts.print_metadata = False
    return comp.as_hlo_module().to_string(opts)


class ArtifactBuilder:
    """Accumulates artifacts and writes the manifest + payload files."""

    def __init__(self, out_dir: str, verbose: bool = True) -> None:
        self.out_dir = out_dir
        self.lines: List[str] = ["version 1"]
        self.verbose = verbose
        self.count = 0
        os.makedirs(out_dir, exist_ok=True)

    def add(
        self,
        name: str,
        fn: Callable,
        inputs: Sequence[InputSpec],
        meta: Dict[str, object],
        output_names: Optional[List[str]] = None,
        golden: bool = False,
    ) -> None:
        """Lower ``fn(*inputs)`` and register it under ``name``."""
        t0 = time.time()
        specs = [jax.ShapeDtypeStruct(i.value.shape, i.value.dtype) for i in inputs]
        lowered = jax.jit(fn).lower(*specs)
        hlo = to_hlo_text(lowered)
        hlo_file = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, hlo_file), "w") as f:
            f.write(hlo)

        out_shapes = jax.eval_shape(fn, *specs)
        if not isinstance(out_shapes, (tuple, list)):
            out_shapes = (out_shapes,)
        if output_names is None:
            output_names = [f"out{i}" for i in range(len(out_shapes))]
        assert len(output_names) == len(out_shapes)

        # Fixture payload: const + state inputs, in manifest order.
        fix_file = ""
        offset = 0
        fix_chunks: List[bytes] = []
        lines = [f"artifact {name}", f"hlo {hlo_file}"]
        for k, v in meta.items():
            lines.append(f"meta {k} {v}")
        for spec in inputs:
            entry = (
                f"input {spec.name} {_dtype_name(spec.value.dtype)} "
                f"{_shape_str(spec.value.shape)} {spec.kind}"
            )
            if spec.kind in ("const", "state"):
                if not fix_file:
                    fix_file = f"{name}.fix.bin"
                raw = spec.value.tobytes()
                entry += f" {fix_file} {offset}"
                offset += len(raw)
                fix_chunks.append(raw)
            lines.append(entry)
        for oname, osh in zip(output_names, out_shapes):
            lines.append(
                f"output {oname} {_dtype_name(osh.dtype)} {_shape_str(osh.shape)}"
            )
        if fix_chunks:
            with open(os.path.join(self.out_dir, fix_file), "wb") as f:
                f.write(b"".join(fix_chunks))

        if golden:
            outs = jax.jit(fn)(*[jnp.asarray(i.value) for i in inputs])
            if not isinstance(outs, (tuple, list)):
                outs = (outs,)
            gfile = f"{name}.golden.bin"
            with open(os.path.join(self.out_dir, gfile), "wb") as f:
                for spec in inputs:
                    if spec.kind == "runtime":
                        f.write(spec.value.tobytes())
                for o in outs:
                    f.write(np.ascontiguousarray(np.array(o)).tobytes())
            lines.append(f"golden {gfile}")

        lines.append("end")
        self.lines.extend(lines)
        self.count += 1
        if self.verbose:
            print(f"  [{self.count}] {name}  ({time.time() - t0:.1f}s, "
                  f"hlo {len(hlo) // 1024}KB)")

    def finish(self) -> None:
        with open(os.path.join(self.out_dir, "manifest.txt"), "w") as f:
            f.write("\n".join(self.lines) + "\n")
        if self.verbose:
            print(f"wrote {self.count} artifacts -> {self.out_dir}/manifest.txt")


# ---------------------------------------------------------------------------
# Conv artifact group (Tables 3/4/11-15)
# ---------------------------------------------------------------------------

CONV_B, CONV_H = 2, 16  # bench shape; results scale linearly in B*H (§C.4)


def _rand(shape, seed, dtype=np.float32):
    return np.random.default_rng(seed).normal(size=shape).astype(dtype)


def _conv_monarch_artifact(b: ArtifactBuilder, n: int, *, gated: bool,
                           causal: bool, golden: bool) -> None:
    """Fused Monarch conv: u (+gates) and time-domain k as runtime inputs,
    FFT matrices as fixtures; packed coefficients computed in-HLO."""
    input_len = n // 2 if causal else n
    order = conv_op.default_order(n)
    mod = monarch2 if order == 2 else monarch3
    cfg_cls = monarch2.Monarch2Config if order == 2 else monarch3.Monarch3Config
    cfg = cfg_cls(seq_len=n, input_len=input_len, gated=gated)
    kernel_fn = mod.build_conv_fn(cfg)
    consts = mod.constant_operands(cfg)

    def fn(*args):
        if gated:
            u, v, w, k = args[:4]
            rest = args[4:]
            coeffs = conv_op.coeffs_from_padded(conv_op._pad_to(k, n), cfg.factors)
            return (kernel_fn(u, v, w, *coeffs, *rest),)
        u, k = args[:2]
        rest = args[2:]
        coeffs = conv_op.coeffs_from_padded(conv_op._pad_to(k, n), cfg.factors)
        return (kernel_fn(u, *coeffs, *rest),)

    inputs = [InputSpec("u", _rand((CONV_B, CONV_H, input_len), n), "runtime")]
    if gated:
        inputs += [InputSpec("v", _rand((CONV_B, CONV_H, input_len), n + 1), "runtime"),
                   InputSpec("w", _rand((CONV_B, CONV_H, input_len), n + 2), "runtime")]
    inputs.append(InputSpec("k", _rand((CONV_H, input_len), n + 3), "runtime"))
    inputs += [InputSpec(cname, arr, "const") for cname, arr in consts.items()]
    kind = ("conv_gated" if gated else "conv_causal" if causal else "conv_fwd")
    name = f"{kind}_monarch_n{input_len}"
    b.add(name, fn, inputs,
          meta=dict(group="conv", kind=kind, variant="monarch", seq_len=input_len,
                    fft_len=n, order=order, batch=CONV_B, heads=CONV_H),
          output_names=["y"], golden=golden)


def _conv_baseline_artifact(b: ArtifactBuilder, n: int, *, gated: bool,
                            causal: bool, golden: bool) -> None:
    """The PyTorch-analogue baseline: plain jnp.fft conv lowered to HLO."""
    input_len = n // 2 if causal else n

    if gated:
        def fn(u, v, w, k):
            return ((ref.fft_conv_gated_causal if causal else ref.fft_conv_gated)(u, v, w, k),)
    elif causal:
        def fn(u, k):
            return (ref.fft_conv_causal(u, k),)
    else:
        def fn(u, k):
            return (ref.fft_conv(u, k),)

    inputs = [InputSpec("u", _rand((CONV_B, CONV_H, input_len), n), "runtime")]
    if gated:
        inputs += [InputSpec("v", _rand((CONV_B, CONV_H, input_len), n + 1), "runtime"),
                   InputSpec("w", _rand((CONV_B, CONV_H, input_len), n + 2), "runtime")]
    inputs.append(InputSpec("k", _rand((CONV_H, input_len), n + 3), "runtime"))
    kind = ("conv_gated" if gated else "conv_causal" if causal else "conv_fwd")
    name = f"{kind}_baseline_n{input_len}"
    b.add(name, fn, inputs,
          meta=dict(group="conv", kind=kind, variant="baseline", seq_len=input_len,
                    fft_len=n, batch=CONV_B, heads=CONV_H),
          output_names=["y"], golden=golden)


def _conv_bwd_artifacts(b: ArtifactBuilder, n: int, golden: bool) -> None:
    """Backward pass (Table 15): (u, k, dy) -> (du, dk), both variants."""
    order = conv_op.default_order(n)

    def fn_m(u, k, dy):
        _, vjp = jax.vjp(lambda u_, k_: conv_op.long_conv_circular(u_, k_, order), u, k)
        return vjp(dy)

    def fn_b(u, k, dy):
        _, vjp = jax.vjp(ref.fft_conv, u, k)
        return vjp(dy)

    inputs = [InputSpec("u", _rand((CONV_B, CONV_H, n), n), "runtime"),
              InputSpec("k", _rand((CONV_H, n), n + 3), "runtime"),
              InputSpec("dy", _rand((CONV_B, CONV_H, n), n + 4), "runtime")]
    b.add(f"conv_bwd_monarch_n{n}", fn_m, inputs,
          meta=dict(group="conv", kind="conv_bwd", variant="monarch", seq_len=n,
                    fft_len=n, order=order, batch=CONV_B, heads=CONV_H),
          output_names=["du", "dk"], golden=golden)
    b.add(f"conv_bwd_baseline_n{n}", fn_b, inputs,
          meta=dict(group="conv", kind="conv_bwd", variant="baseline", seq_len=n,
                    fft_len=n, batch=CONV_B, heads=CONV_H),
          output_names=["du", "dk"], golden=golden)


def build_conv_group(b: ArtifactBuilder, seqlens: Sequence[int]) -> None:
    for n in seqlens:
        golden = n <= 4096
        _conv_monarch_artifact(b, n, gated=False, causal=False, golden=golden)
        _conv_baseline_artifact(b, n, gated=False, causal=False, golden=golden)
        _conv_monarch_artifact(b, n, gated=True, causal=False, golden=golden)
        _conv_baseline_artifact(b, n, gated=True, causal=False, golden=golden)
        # Causal: input length n/2, FFT size n (Table 13's configuration).
        _conv_monarch_artifact(b, n, gated=False, causal=True, golden=golden)
        _conv_baseline_artifact(b, n, gated=False, causal=True, golden=golden)
        if n <= 16384:
            _conv_bwd_artifacts(b, n, golden=golden)


def build_ablation_group(b: ArtifactBuilder, seqlens: Sequence[int]) -> None:
    """Table 3 ablations: complex path (no r2c), 4-mult complex matmuls."""
    for n in seqlens:
        for tag, r2c, karatsuba in (("basic", False, True), ("r2c4m", True, False)):
            cfg = monarch2.Monarch2Config(seq_len=n, input_len=n, r2c=r2c,
                                          karatsuba=karatsuba)
            kernel_fn = monarch2.build_conv_fn(cfg)
            consts = monarch2.constant_operands(cfg)

            def fn(u, k, *rest, _cfg=cfg, _kfn=kernel_fn, _r2c=r2c):
                if _r2c:
                    coeffs = conv_op.coeffs_from_padded(k, _cfg.factors)
                    return (_kfn(u, *coeffs, *rest),)
                kf = jnp.fft.fft(k.astype(jnp.float32), axis=-1)
                # Reshape-transpose permutation (monarch_permute): gather at
                # these shapes miscompiles on the runtime's XLA 0.5.1.
                kr = conv_op.monarch_permute(jnp.real(kf), _cfg.factors)
                ki = conv_op.monarch_permute(jnp.imag(kf), _cfg.factors)
                return (_kfn(u, kr, ki, *rest),)

            inputs = [InputSpec("u", _rand((CONV_B, CONV_H, n), n), "runtime"),
                      InputSpec("k", _rand((CONV_H, n), n + 3), "runtime")]
            inputs += [InputSpec(cn, arr, "const") for cn, arr in consts.items()]
            b.add(f"conv_abl_{tag}_n{n}", fn, inputs,
                  meta=dict(group="ablation", kind="conv_fwd", variant=tag,
                            seq_len=n, fft_len=n, order=2, batch=CONV_B, heads=CONV_H),
                  output_names=["y"], golden=True)


def build_sparse_group(b: ArtifactBuilder, n: int = 4096) -> None:
    """Table 9/10: frequency-sparse conv artifacts, one per pattern."""
    n1, n2 = fftmats.monarch_factors(n, 2)
    for tag, pat in fftmats.table10_patterns(n1, n2).items():
        cfg = monarch2.Monarch2Config(seq_len=n, input_len=n, r2c=False,
                                      keep_rows=pat.keep_rows, keep_cols=pat.keep_cols)
        kernel_fn = monarch2.build_conv_fn(cfg)
        consts = monarch2.constant_operands(cfg)

        def fn(u, k, *rest, _cfg=cfg, _kfn=kernel_fn, _p=pat):
            kfr, kfi = conv_op.kf_mon_sliced(k, _cfg.factors, _p.keep_rows, _p.keep_cols)
            return (_kfn(u, kfr, kfi, *rest),)

        inputs = [InputSpec("u", _rand((CONV_B, CONV_H, n), n), "runtime"),
                  InputSpec("k", _rand((CONV_H, n), n + 3), "runtime")]
        inputs += [InputSpec(cn, arr, "const") for cn, arr in consts.items()]
        b.add(f"conv_sparse_{tag}_n{n}", fn, inputs,
              meta=dict(group="sparse", kind="conv_fwd", variant=f"sparse_{tag}",
                        seq_len=n, fft_len=n, order=2, batch=CONV_B, heads=CONV_H,
                        sparsity=f"{pat.sparsity_fraction:.4f}",
                        flop_fraction=f"{pat.matmul_flop_fraction:.4f}",
                        keep_rows=pat.keep_rows, keep_cols=pat.keep_cols),
              output_names=["y"], golden=True)


# ---------------------------------------------------------------------------
# Model artifact groups
# ---------------------------------------------------------------------------


def _flat_train_fn(cfg: M.ModelConfig, opt: M.AdamConfig, names: List[str],
                   extra_inputs: int = 1):
    """Flatten make_train_step over sorted param names for AOT lowering."""
    ts = (M.make_classifier_train_step(cfg, opt) if cfg.mixer == "longconv"
          else M.make_train_step(cfg, opt))
    p = len(names)

    def fn(*args):
        params = dict(zip(names, args[:p]))
        m = dict(zip(names, args[p:2 * p]))
        v = dict(zip(names, args[2 * p:3 * p]))
        step = args[3 * p]
        data = args[3 * p + 1: 3 * p + 1 + extra_inputs]
        p2, m2, v2, s2, loss = ts(params, m, v, step, *data)
        return (tuple(p2[n] for n in names) + tuple(m2[n] for n in names)
                + tuple(v2[n] for n in names) + (s2, loss))

    return fn


def _state_inputs(params: M.Params, names: List[str]) -> List[InputSpec]:
    specs = [InputSpec(f"param.{n}", np.array(params[n]), "state") for n in names]
    specs += [InputSpec(f"adam_m.{n}", np.zeros_like(np.array(params[n])), "state")
              for n in names]
    specs += [InputSpec(f"adam_v.{n}", np.zeros_like(np.array(params[n])), "state")
              for n in names]
    specs.append(InputSpec("step", np.array(0.0, dtype=np.float32), "state"))
    return specs


def _state_output_names(names: List[str]) -> List[str]:
    return ([f"param.{n}" for n in names] + [f"adam_m.{n}" for n in names]
            + [f"adam_v.{n}" for n in names] + ["step"])


def add_train_artifact(b: ArtifactBuilder, name: str, cfg: M.ModelConfig,
                       opt: M.AdamConfig, batch: int, seed: int = 0,
                       extra_meta: Optional[Dict[str, object]] = None) -> None:
    params = M.init_params(cfg, seed=seed)
    names, _ = M.flatten_params(params)
    inputs = _state_inputs(params, names)
    if cfg.mixer == "longconv":
        inputs += [InputSpec("pixels", _rand((batch, cfg.seq_len), 7), "runtime"),
                   InputSpec("labels", np.zeros(batch, dtype=np.int32), "runtime")]
        extra = 2
    else:
        tok = np.random.default_rng(7).integers(
            0, cfg.vocab, size=(batch, cfg.seq_len + 1)).astype(np.int32)
        inputs.append(InputSpec("tokens", tok, "runtime"))
        extra = 1
    fn = _flat_train_fn(cfg, opt, names, extra_inputs=extra)
    meta = dict(group="model", kind="train_step", mixer=cfg.mixer,
                variant=cfg.conv_impl, seq_len=cfg.seq_len, dim=cfg.dim,
                layers=cfg.layers, vocab=cfg.vocab, batch=batch,
                n_state=3 * len(names) + 1,
                n_params=M.ModelConfig.param_count(params))
    meta.update(extra_meta or {})
    b.add(name, fn, inputs, meta=meta,
          output_names=_state_output_names(names) + ["loss"])


def add_eval_artifact(b: ArtifactBuilder, name: str, cfg: M.ModelConfig,
                      batch: int, *, kmask: bool = False, logits: bool = False,
                      seed: int = 0, golden: bool = False,
                      extra_meta: Optional[Dict[str, object]] = None) -> None:
    """Loss (or logits) forward artifact; params are state inputs."""
    params = M.init_params(cfg, seed=seed)
    names, _ = M.flatten_params(params)
    p = len(names)

    def fn(*args):
        pd = dict(zip(names, args[:p]))
        tokens = args[p]
        mask = args[p + 1] if kmask else None
        if logits:
            return (M.lm_forward(pd, tokens, cfg, mask),)
        return (M.lm_loss(pd, tokens, cfg, mask),)

    inputs = [InputSpec(f"param.{n}", np.array(params[n]), "state") for n in names]
    ltok = cfg.seq_len if logits else cfg.seq_len + 1
    tok = np.random.default_rng(9).integers(0, cfg.vocab, size=(batch, ltok)).astype(np.int32)
    inputs.append(InputSpec("tokens", tok, "runtime"))
    if kmask:
        inputs.append(InputSpec("kmask", np.ones(cfg.k_len, dtype=np.float32), "runtime"))
    meta = dict(group="model", kind="lm_logits" if logits else "lm_eval",
                mixer=cfg.mixer, variant=cfg.conv_impl, seq_len=cfg.seq_len,
                dim=cfg.dim, layers=cfg.layers, vocab=cfg.vocab, batch=batch,
                n_state=p)
    meta.update(extra_meta or {})
    b.add(name, fn, inputs, meta=meta,
          output_names=["logits" if logits else "loss"], golden=golden)


def build_lm_group(b: ArtifactBuilder, dim: int, layers: int, seq: int,
                   batch: int, vocab: int) -> None:
    opt = M.AdamConfig()
    base = M.ModelConfig(vocab=vocab, dim=dim, layers=layers, seq_len=seq)
    # Tiny config for fast Rust integration tests.
    tiny = M.ModelConfig(vocab=32, dim=16, layers=1, seq_len=64)
    add_train_artifact(b, "lm_tiny_train", tiny, opt, batch=2)
    add_eval_artifact(b, "lm_tiny_eval", tiny, batch=2, golden=True)

    # Table 1: same architecture, monarch vs baseline conv, same data shape.
    add_train_artifact(b, "lm_train_monarch", base, opt, batch=batch)
    add_train_artifact(b, "lm_train_baseline",
                       M.ModelConfig(**{**base.__dict__, "conv_impl": "baseline"}),
                       opt, batch=batch)
    # Table 7/8: eval with a runtime filter mask (partial convolutions).
    add_eval_artifact(b, "lm_eval_kmask", base, batch=batch, kmask=True)
    # Serving logits (Table 5 / server example).
    add_eval_artifact(b, "lm_fwd_logits", base, batch=batch, logits=True)
    # Table 9: frequency-sparse eval at several Table 10 patterns.
    n1, n2 = fftmats.monarch_factors(seq, 2)  # fft size = 2*seq; factors of seq
    for tag, keep in (("s50", (n1 // 2, n2)), ("s75", (n1 // 2, n2 // 2)),
                      ("s91", (n1 // 4, n2 * 3 // 8))):
        cfg_s = M.ModelConfig(**{**base.__dict__, "sparse_block": keep})
        frac = 1.0 - (keep[0] * keep[1]) / (n1 * n2)
        add_eval_artifact(b, f"lm_eval_sparse_{tag}", cfg_s, batch=batch,
                          extra_meta=dict(sparsity=f"{frac:.4f}"))


def build_e2e_group(b: ArtifactBuilder) -> None:
    """Table 5 model zoo: each model in monarch and baseline conv variants."""
    zoo = [
        ("m2bert", M.ModelConfig(vocab=128, dim=64, layers=2, seq_len=128), 8),
        ("hyena4k", M.ModelConfig(vocab=128, dim=32, layers=2, seq_len=4096), 1),
        ("sashimi", M.ModelConfig(vocab=64, dim=32, layers=2, seq_len=8192,
                                  mixer="longconv", filter_len=4096), 1),
        ("hyenadna", M.ModelConfig(vocab=8, dim=16, layers=2, seq_len=16384), 1),
    ]
    for tag, cfg, batch in zoo:
        for impl in ("monarch", "baseline"):
            cfg_i = M.ModelConfig(**{**cfg.__dict__, "conv_impl": impl})
            if cfg.mixer == "longconv":
                add_clf_eval_artifact(b, f"e2e_{tag}_{impl}", cfg_i, batch,
                                      extra_meta=dict(group="e2e", model=tag))
            else:
                add_eval_artifact(b, f"e2e_{tag}_{impl}", cfg_i, batch=batch,
                                  extra_meta=dict(group="e2e", model=tag))


def build_attn_group(b: ArtifactBuilder) -> None:
    """Table 6: Hyena vs GPT at matched dims across sequence lengths."""
    for seq in (256, 1024, 4096):
        for mixer in ("hyena", "attention"):
            cfg = M.ModelConfig(vocab=128, dim=64, layers=2, seq_len=seq,
                                mixer=mixer, heads=4)
            add_eval_artifact(b, f"t6_{mixer}_n{seq}", cfg, batch=1,
                              extra_meta=dict(group="attn", model=mixer))


def add_clf_eval_artifact(b: ArtifactBuilder, name: str, cfg: M.ModelConfig,
                          batch: int, golden: bool = False,
                          extra_meta: Optional[Dict[str, object]] = None) -> None:
    params = M.init_params(cfg, seed=0)
    names, _ = M.flatten_params(params)
    p = len(names)

    def fn(*args):
        pd = dict(zip(names, args[:p]))
        return (M.classifier_forward(pd, args[p], cfg),)

    inputs = [InputSpec(f"param.{n}", np.array(params[n]), "state") for n in names]
    inputs.append(InputSpec("pixels", _rand((batch, cfg.seq_len), 11), "runtime"))
    meta = dict(group="model", kind="clf_logits", mixer=cfg.mixer,
                variant=cfg.conv_impl, seq_len=cfg.seq_len, dim=cfg.dim,
                layers=cfg.layers, batch=batch, n_state=p)
    meta.update(extra_meta or {})
    b.add(name, fn, inputs, meta=meta, output_names=["logits"], golden=golden)


def build_pathfinder_group(b: ArtifactBuilder) -> None:
    """Table 2 analogue: long-conv classifier on synthetic Pathfinder."""
    opt = M.AdamConfig(lr=3e-3)
    cfg = M.ModelConfig(vocab=4, dim=48, layers=2, seq_len=1024, mixer="longconv")
    add_train_artifact(b, "pf_train", cfg, opt, batch=8,
                       extra_meta=dict(task="pathfinder"))
    add_clf_eval_artifact(b, "pf_eval", cfg, batch=8,
                          extra_meta=dict(task="pathfinder"))


def build_dna_group(b: ArtifactBuilder) -> None:
    """Table 8 analogue: partial-conv DNA model + extension eval."""
    opt = M.AdamConfig(lr=2e-3)
    cfg = M.ModelConfig(vocab=8, dim=24, layers=2, seq_len=4096, filter_len=1024)
    add_train_artifact(b, "dna_train", cfg, opt, batch=1,
                       extra_meta=dict(task="dna"))
    add_eval_artifact(b, "dna_eval", cfg, batch=1, kmask=True,
                      extra_meta=dict(task="dna"))


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

GROUPS = ("conv", "ablation", "sparse", "lm", "e2e", "attn", "pathfinder", "dna")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--groups", default="all",
                    help=f"comma list from {GROUPS} or 'all'")
    ap.add_argument("--conv-seqlens", default="256,1024,4096,16384,65536")
    ap.add_argument("--lm-dim", type=int, default=64)
    ap.add_argument("--lm-layers", type=int, default=2)
    ap.add_argument("--lm-seq", type=int, default=256)
    ap.add_argument("--lm-batch", type=int, default=4)
    ap.add_argument("--lm-vocab", type=int, default=128)
    args = ap.parse_args()

    groups = GROUPS if args.groups == "all" else tuple(args.groups.split(","))
    seqlens = [int(s) for s in args.conv_seqlens.split(",")]
    b = ArtifactBuilder(args.out_dir)
    t0 = time.time()
    if "conv" in groups:
        build_conv_group(b, seqlens)
    if "ablation" in groups:
        build_ablation_group(b, [1024, 4096])
    if "sparse" in groups:
        build_sparse_group(b)
    if "lm" in groups:
        build_lm_group(b, args.lm_dim, args.lm_layers, args.lm_seq,
                       args.lm_batch, args.lm_vocab)
    if "e2e" in groups:
        build_e2e_group(b)
    if "attn" in groups:
        build_attn_group(b)
    if "pathfinder" in groups:
        build_pathfinder_group(b)
    if "dna" in groups:
        build_dna_group(b)
    b.finish()
    print(f"total {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()
