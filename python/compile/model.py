"""L2: JAX model definitions built on the FlashFFTConv kernels.

Everything here is build-time Python: `aot.py` lowers these functions once
to HLO text, and the Rust coordinator drives them through PJRT.  The module
provides the three model families the paper evaluates:

  * **Hyena-style gated-convolution LM** (Tables 1, 5, 6, 7, 9): stacked
    blocks of ``y = v * ((u*w) conv k)`` with implicitly-parameterized
    filters (an MLP over positional features, modulated by an exponential
    decay window — the Hyena filter of [94]), tied-embedding next-token
    loss, Adam-in-jnp training step.
  * **GPT-style attention LM** (Table 6 comparator): identical skeleton
    with causal multi-head attention as the mixer.
  * **Long-conv Pathfinder classifier** (Table 2): non-gated long convs +
    mean pooling over a flattened synthetic Pathfinder image.

Every model exists in two convolution implementations, selected by
``ModelConfig.conv_impl``:

  * ``"monarch"``  — the fused Pallas FlashFFTConv (custom-VJP ops);
  * ``"baseline"`` — the standard `jnp.fft` convolution (the paper's
    PyTorch-baseline analogue), natively differentiable.

Parameters are plain ``dict[str, jnp.ndarray]`` with deterministic
(sorted-key) flattening so the Rust side can hold and feed them as a flat
buffer list — see :func:`flatten_params`.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import conv_op, ref

Params = Dict[str, jnp.ndarray]


# ---------------------------------------------------------------------------
# Configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static architecture configuration (baked into each artifact)."""

    vocab: int = 128
    dim: int = 128
    layers: int = 2
    seq_len: int = 256
    mixer: str = "hyena"          # "hyena" | "attention" | "longconv"
    conv_impl: str = "monarch"    # "monarch" | "baseline"
    conv_order: int = 0           # 0 = pick via cost-model heuristic
    heads: int = 4                # attention only
    mlp_expand: int = 2
    filter_feats: int = 9         # positional feature dim for Hyena filters
    filter_hidden: int = 32       # Hyena filter-MLP width
    filter_len: int = 0           # 0 = full length; <seq_len = partial conv (§3.3)
    sparse_block: Tuple[int, int] = (0, 0)  # (kr, kc): freq-sparse eval (§3.3)
    n_classes: int = 2            # classifier head (longconv mixer)

    @property
    def order(self) -> int:
        return self.conv_order or conv_op.default_order(2 * self.seq_len)

    @property
    def k_len(self) -> int:
        return self.filter_len or self.seq_len

    @staticmethod
    def param_count(params: Params) -> int:
        return int(sum(int(np.prod(p.shape)) for p in params.values()))


# ---------------------------------------------------------------------------
# Small building blocks
# ---------------------------------------------------------------------------


def rmsnorm(x: jnp.ndarray, g: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """RMSNorm along the channel axis."""
    scale = jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return x * scale * g


def _uniform(rng: np.random.Generator, shape, scale: float) -> jnp.ndarray:
    return jnp.asarray(rng.uniform(-scale, scale, size=shape).astype(np.float32))


def _linear_init(rng: np.random.Generator, d_in: int, d_out: int) -> jnp.ndarray:
    return _uniform(rng, (d_in, d_out), 1.0 / np.sqrt(d_in))


def positional_features(seq_len: int, n_feats: int) -> jnp.ndarray:
    """Hyena-style positional features: normalized time + sin/cos bands."""
    t = np.arange(seq_len, dtype=np.float32) / seq_len
    feats = [t[:, None]]
    n_bands = (n_feats - 1) // 2
    for i in range(n_bands):
        f = 2.0 ** i
        feats.append(np.sin(2 * np.pi * f * t)[:, None])
        feats.append(np.cos(2 * np.pi * f * t)[:, None])
    out = np.concatenate(feats, axis=1)[:, :n_feats].astype(np.float32)
    return jnp.asarray(out)


def decay_window(seq_len: int, dim: int) -> jnp.ndarray:
    """Per-channel exponential decay modulation (Hyena's window)."""
    t = np.arange(seq_len, dtype=np.float32)[None, :]
    rates = np.geomspace(1e-3, 0.3, dim).astype(np.float32)[:, None]
    return jnp.asarray(np.exp(-rates * t))


# ---------------------------------------------------------------------------
# Hyena filter + mixers
# ---------------------------------------------------------------------------


def hyena_filter(params: Params, prefix: str, cfg: ModelConfig) -> jnp.ndarray:
    """Generate the (dim, k_len) implicit filter bank for one layer.

    MLP over positional features -> per-channel filters, modulated by an
    exponential decay window; regenerated every forward pass (the workload
    FlashFFTConv's on-the-fly ``k_f`` computation serves — §C.2).
    """
    feats = positional_features(cfg.k_len, cfg.filter_feats)
    h = jnp.sin(feats @ params[f"{prefix}.fw1"] + params[f"{prefix}.fb1"])
    h = jnp.sin(h @ params[f"{prefix}.fw2"] + params[f"{prefix}.fb2"])
    k = (h @ params[f"{prefix}.fw3"]).T  # (dim, k_len)
    window = decay_window(cfg.k_len, cfg.dim)
    return k * window


def _pad_filter(k: jnp.ndarray, length: int) -> jnp.ndarray:
    """Zero-pad a (possibly partial, §3.3) filter to the input length."""
    if k.shape[-1] == length:
        return k
    return jnp.concatenate(
        [k, jnp.zeros(k.shape[:-1] + (length - k.shape[-1],), k.dtype)], axis=-1
    )


def _conv_seq(cfg: ModelConfig, u, v, w, k) -> jnp.ndarray:
    """Dispatch the gated causal conv to the configured implementation.

    Inputs/outputs channel-major ``(B, D, L)`` as the kernels expect.
    """
    kr, kc = cfg.sparse_block
    if kr:
        return conv_op.sparse_gated_conv_causal(u, v, w, k, kr, kc)
    if cfg.conv_impl == "monarch":
        return conv_op.gated_conv_causal(u, v, w, k, cfg.order)
    return ref.fft_conv_gated_causal(u, v, w, _pad_filter(k, u.shape[-1]))


def hyena_block(params: Params, prefix: str, x: jnp.ndarray, cfg: ModelConfig,
                kmask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """One Hyena block: gated long conv mixer + channel MLP, both residual."""
    h = rmsnorm(x, params[f"{prefix}.norm1"])
    proj = h @ params[f"{prefix}.win"]  # (B, L, 3D)
    u, v, w = jnp.split(proj, 3, axis=-1)
    k = hyena_filter(params, prefix, cfg)
    if kmask is not None:
        k = k * kmask[None, : cfg.k_len]  # partial-conv truncation (Table 7)
    ut, vt, wt = (t.transpose(0, 2, 1) for t in (u, v, w))  # (B, D, L)
    y = _conv_seq(cfg, ut, vt, wt, k).transpose(0, 2, 1)
    x = x + y @ params[f"{prefix}.wout"]

    h = rmsnorm(x, params[f"{prefix}.norm2"])
    h = jax.nn.gelu(h @ params[f"{prefix}.w1"])
    return x + h @ params[f"{prefix}.w2"]


def attention_block(params: Params, prefix: str, x: jnp.ndarray, cfg: ModelConfig,
                    kmask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """One GPT block: causal MHA mixer + channel MLP (Table 6 comparator)."""
    del kmask
    b, l, d = x.shape
    nh, hd = cfg.heads, d // cfg.heads
    h = rmsnorm(x, params[f"{prefix}.norm1"])
    qkv = h @ params[f"{prefix}.wqkv"]
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(b, l, nh, hd).transpose(0, 2, 1, 3)
    k = k.reshape(b, l, nh, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, l, nh, hd).transpose(0, 2, 1, 3)
    scores = jnp.einsum("bhid,bhjd->bhij", q, k) / np.sqrt(hd)
    mask = jnp.tril(jnp.ones((l, l), dtype=bool))
    scores = jnp.where(mask, scores, -1e9)
    att = jax.nn.softmax(scores, axis=-1)
    y = jnp.einsum("bhij,bhjd->bhid", att, v).transpose(0, 2, 1, 3).reshape(b, l, d)
    x = x + y @ params[f"{prefix}.wout"]

    h = rmsnorm(x, params[f"{prefix}.norm2"])
    h = jax.nn.gelu(h @ params[f"{prefix}.w1"])
    return x + h @ params[f"{prefix}.w2"]


def longconv_block(params: Params, prefix: str, x: jnp.ndarray, cfg: ModelConfig,
                   kmask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Plain (non-gated) long-conv block — the [44]-style Path-X model."""
    h = rmsnorm(x, params[f"{prefix}.norm1"])
    k = hyena_filter(params, prefix, cfg)
    if kmask is not None:
        k = k * kmask[None, : cfg.k_len]
    ht = h.transpose(0, 2, 1)
    kr, kc = cfg.sparse_block
    if kr:
        y = conv_op.sparse_long_conv_causal(ht, k, kr, kc)
    elif cfg.conv_impl == "monarch":
        y = conv_op.long_conv_causal(ht, k, cfg.order)
    else:
        y = ref.fft_conv_causal(ht, _pad_filter(k, ht.shape[-1]))
    y = jax.nn.gelu(y.transpose(0, 2, 1))
    x = x + y @ params[f"{prefix}.wout"]

    h = rmsnorm(x, params[f"{prefix}.norm2"])
    h = jax.nn.gelu(h @ params[f"{prefix}.w1"])
    return x + h @ params[f"{prefix}.w2"]


_BLOCKS = {"hyena": hyena_block, "attention": attention_block, "longconv": longconv_block}


# ---------------------------------------------------------------------------
# Parameter initialization
# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig, seed: int = 0) -> Params:
    """Initialize all model parameters (sorted-key dict; see module doc)."""
    rng = np.random.default_rng(seed)
    d, fd, fh = cfg.dim, cfg.filter_feats, cfg.filter_hidden
    p: Params = {}
    if cfg.mixer == "longconv":
        # Classifier head; no token embedding (an unused parameter would be
        # pruned from the compiled executable's signature by the runtime's
        # XLA, desynchronizing the manifest).
        p["head"] = _linear_init(rng, d, cfg.n_classes)
        p["pix_embed"] = _linear_init(rng, 1, d)
    else:
        p["embed"] = _uniform(rng, (cfg.vocab, d), 0.02)
    p["norm_f"] = jnp.ones(d)
    for i in range(cfg.layers):
        pre = f"layer{i}"
        p[f"{pre}.norm1"] = jnp.ones(d)
        p[f"{pre}.norm2"] = jnp.ones(d)
        p[f"{pre}.w1"] = _linear_init(rng, d, cfg.mlp_expand * d)
        p[f"{pre}.w2"] = _linear_init(rng, cfg.mlp_expand * d, d)
        p[f"{pre}.wout"] = _linear_init(rng, d, d)
        if cfg.mixer == "attention":
            p[f"{pre}.wqkv"] = _linear_init(rng, d, 3 * d)
        else:
            if cfg.mixer == "hyena":
                p[f"{pre}.win"] = _linear_init(rng, d, 3 * d)
            p[f"{pre}.fw1"] = _linear_init(rng, fd, fh)
            p[f"{pre}.fb1"] = jnp.zeros(fh)
            p[f"{pre}.fw2"] = _linear_init(rng, fh, fh)
            p[f"{pre}.fb2"] = jnp.zeros(fh)
            p[f"{pre}.fw3"] = _linear_init(rng, fh, d)
    return p


def flatten_params(params: Params) -> Tuple[List[str], List[jnp.ndarray]]:
    """Deterministic (sorted-key) flattening shared with the Rust runtime."""
    names = sorted(params.keys())
    return names, [params[n] for n in names]


def unflatten_params(names: List[str], leaves: List[jnp.ndarray]) -> Params:
    return dict(zip(names, leaves))


# ---------------------------------------------------------------------------
# Forward passes and losses
# ---------------------------------------------------------------------------


def lm_forward(params: Params, tokens: jnp.ndarray, cfg: ModelConfig,
               kmask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Token LM forward: (B, L) int32 -> (B, L, vocab) logits (tied embed)."""
    x = params["embed"][tokens]
    block = _BLOCKS[cfg.mixer]
    for i in range(cfg.layers):
        x = block(params, f"layer{i}", x, cfg, kmask)
    x = rmsnorm(x, params["norm_f"])
    return x @ params["embed"].T


def lm_loss(params: Params, tokens: jnp.ndarray, cfg: ModelConfig,
            kmask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Mean next-token cross-entropy over the batch."""
    logits = lm_forward(params, tokens[:, :-1], cfg, kmask)
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def classifier_forward(params: Params, pixels: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Pathfinder classifier: (B, L) f32 pixels -> (B, n_classes) logits."""
    x = pixels[..., None] @ params["pix_embed"]
    for i in range(cfg.layers):
        x = longconv_block(params, f"layer{i}", x, cfg)
    x = rmsnorm(x, params["norm_f"])
    return jnp.mean(x, axis=1) @ params["head"]


def classifier_loss(params: Params, pixels: jnp.ndarray, labels: jnp.ndarray,
                    cfg: ModelConfig) -> jnp.ndarray:
    logits = classifier_forward(params, pixels, cfg)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))


# ---------------------------------------------------------------------------
# Adam-in-jnp training step (optax is unavailable offline; DESIGN.md §3)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    lr: float = 3e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    grad_clip: float = 1.0


def adam_step(params: Params, m: Params, v: Params, step: jnp.ndarray,
              grads: Params, opt: AdamConfig) -> Tuple[Params, Params, Params]:
    """One Adam update with global-norm clipping; ``step`` is 1-based f32."""
    gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in grads.values()) + 1e-12)
    scale = jnp.minimum(1.0, opt.grad_clip / gnorm)
    new_p, new_m, new_v = {}, {}, {}
    bc1 = 1.0 - opt.b1 ** step
    bc2 = 1.0 - opt.b2 ** step
    for name, g in grads.items():
        g = g * scale
        mi = opt.b1 * m[name] + (1 - opt.b1) * g
        vi = opt.b2 * v[name] + (1 - opt.b2) * g * g
        upd = (mi / bc1) / (jnp.sqrt(vi / bc2) + opt.eps)
        new_p[name] = params[name] - opt.lr * upd
        new_m[name] = mi
        new_v[name] = vi
    return new_p, new_m, new_v


def make_train_step(cfg: ModelConfig, opt: AdamConfig):
    """Build ``train_step(params, m, v, step, tokens) -> (..., loss)``.

    The returned function is what `aot.py` lowers: one fused HLO module
    containing forward, backward (through the custom-VJP Monarch convs),
    and the Adam update.  The Rust trainer holds (params, m, v, step) as
    opaque buffers and loops.
    """

    def train_step(params: Params, m: Params, v: Params, step: jnp.ndarray,
                   tokens: jnp.ndarray):
        loss, grads = jax.value_and_grad(lambda p: lm_loss(p, tokens, cfg))(params)
        step = step + 1.0
        params, m, v = adam_step(params, m, v, step, grads, opt)
        return params, m, v, step, loss

    return train_step


def make_classifier_train_step(cfg: ModelConfig, opt: AdamConfig):
    """Same contract as :func:`make_train_step`, for the Pathfinder task."""

    def train_step(params: Params, m: Params, v: Params, step: jnp.ndarray,
                   pixels: jnp.ndarray, labels: jnp.ndarray):
        loss, grads = jax.value_and_grad(
            lambda p: classifier_loss(p, pixels, labels, cfg)
        )(params)
        step = step + 1.0
        params, m, v = adam_step(params, m, v, step, grads, opt)
        return params, m, v, step, loss

    return train_step
