"""Gradient-correctness tests for the custom-VJP FlashFFTConv ops."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv_op, ref

TOL = dict(rtol=3e-3, atol=3e-3)


def rand(shape, seed):
    return jnp.asarray(np.random.default_rng(seed).normal(size=shape).astype(np.float32))


class TestForward:
    @settings(max_examples=8, deadline=None)
    @given(logl=st.integers(min_value=4, max_value=10), seed=st.integers(0, 2**31))
    def test_long_conv_matches_ref(self, logl, seed):
        l = 1 << logl
        u, k = rand((2, 2, l), seed), rand((2, l), seed + 1)
        got = conv_op.long_conv_causal(u, k, 2)
        want = ref.fft_conv_causal(u, k)
        np.testing.assert_allclose(np.array(got), np.array(want), **TOL)

    def test_partial_filter_shorter_than_input(self):
        l, lk = 256, 64
        u, k = rand((2, 2, l), 0), rand((2, lk), 1)
        got = conv_op.long_conv_causal(u, k, 2)
        kpad = jnp.concatenate([k, jnp.zeros((2, l - lk))], axis=-1)
        want = ref.fft_conv_causal(u, kpad)
        np.testing.assert_allclose(np.array(got), np.array(want), **TOL)

    def test_filter_longer_than_fft_raises(self):
        u, k = rand((1, 1, 32), 0), rand((1, 128), 1)
        with pytest.raises(ValueError):
            conv_op.long_conv_causal(u, k, 2)

    def test_default_order_heuristic(self):
        assert conv_op.default_order(1024) == 2
        assert conv_op.default_order(32768) == 2
        assert conv_op.default_order(65536) == 3


class TestGradients:
    @settings(max_examples=5, deadline=None)
    @given(logl=st.integers(min_value=4, max_value=8), seed=st.integers(0, 2**31))
    def test_gated_grads_match_ref(self, logl, seed):
        l = 1 << logl
        u, v, w = (rand((2, 2, l), seed + i) for i in range(3))
        k = rand((2, l), seed + 9)

        def ours(u, v, w, k):
            return jnp.sum(jnp.sin(conv_op.gated_conv_causal(u, v, w, k, 2)))

        def theirs(u, v, w, k):
            return jnp.sum(jnp.sin(ref.fft_conv_gated_causal(u, v, w, k)))

        g1 = jax.grad(ours, argnums=(0, 1, 2, 3))(u, v, w, k)
        g2 = jax.grad(theirs, argnums=(0, 1, 2, 3))(u, v, w, k)
        for a, b in zip(g1, g2):
            np.testing.assert_allclose(np.array(a), np.array(b), rtol=1e-2, atol=1e-2)

    def test_plain_grads_partial_filter(self):
        l, lk = 128, 32
        u, k = rand((2, 2, l), 5), rand((2, lk), 6)

        def ours(u, k):
            return jnp.sum(jnp.tanh(conv_op.long_conv_causal(u, k, 2)))

        def theirs(u, kfull):
            return jnp.sum(jnp.tanh(ref.fft_conv_causal(u, kfull)))

        g1 = jax.grad(ours, argnums=(0, 1))(u, k)
        kfull = jnp.concatenate([k, jnp.zeros((2, l - lk))], axis=-1)
        g2 = jax.grad(theirs, argnums=(0, 1))(u, kfull)
        np.testing.assert_allclose(np.array(g1[0]), np.array(g2[0]), rtol=1e-2, atol=1e-2)
        np.testing.assert_allclose(np.array(g1[1]), np.array(g2[1][..., :lk]), rtol=1e-2, atol=1e-2)

    def test_order3_grads(self):
        l = 256
        u, k = rand((1, 2, l), 7), rand((2, l), 8)
        g1 = jax.grad(lambda u_, k_: jnp.sum(conv_op.long_conv_causal(u_, k_, 3) ** 2),
                      argnums=(0, 1))(u, k)
        g2 = jax.grad(lambda u_, k_: jnp.sum(ref.fft_conv_causal(u_, k_) ** 2),
                      argnums=(0, 1))(u, k)
        np.testing.assert_allclose(np.array(g1[0]), np.array(g2[0]), rtol=1e-2, atol=1e-2)
        np.testing.assert_allclose(np.array(g1[1]), np.array(g2[1]), rtol=1e-2, atol=1e-2)

    def test_vjp_under_jit(self):
        """The whole fwd+bwd must trace and lower (the train_step path)."""
        l = 64
        u, v, w = (rand((1, 1, l), 10 + i) for i in range(3))
        k = rand((1, l), 13)

        @jax.jit
        def step(u, v, w, k):
            return jax.grad(
                lambda k_: jnp.sum(conv_op.gated_conv_causal(u, v, w, k_, 2) ** 2)
            )(k)

        dk = step(u, v, w, k)
        assert dk.shape == k.shape and bool(jnp.all(jnp.isfinite(dk)))


class TestCoeffs:
    def test_coeffs_match_buildtime(self):
        """jnp coefficient path == numpy build-time path (fftmats)."""
        from compile.kernels import fftmats as fm

        n = 128
        k = np.random.default_rng(3).normal(size=(2, n)).astype(np.float32)
        factors = fm.monarch_factors(n // 2, 2)
        a, b, _ = fm.kf_r2c_monarch(k, factors)
        got = conv_op.coeffs_from_padded(jnp.asarray(k), factors)
        np.testing.assert_allclose(np.array(got[0]), a.real.astype(np.float32), rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.array(got[1]), a.imag.astype(np.float32), rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.array(got[2]), b.real.astype(np.float32), rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.array(got[3]), b.imag.astype(np.float32), rtol=1e-4, atol=1e-4)

    def test_flip_padded_is_spectrum_conjugate(self):
        n = 64
        k = np.random.default_rng(4).normal(size=n).astype(np.float32)
        kf = np.fft.fft(k)
        kflip = np.array(conv_op._flip_padded(jnp.asarray(k)))
        np.testing.assert_allclose(np.fft.fft(kflip), np.conj(kf), rtol=1e-4, atol=1e-4)
