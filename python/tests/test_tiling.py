"""Tiling tests: the B_tile/H_tile knob (§3.1) must not change numerics."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import monarch2 as m2
from compile.kernels import monarch3 as m3
from compile.kernels import ref


def rand(shape, seed):
    return np.random.default_rng(seed).normal(size=shape).astype(np.float32)


def run_m2(cfg, u, k):
    fn = m2.build_conv_fn(cfg)
    ops = list(m2.kernel_operands(cfg, k).values()) + list(
        m2.constant_operands(cfg).values()
    )
    return np.array(fn(jnp.asarray(u), *[jnp.asarray(o) for o in ops]))


class TestOrder2Tiling:
    @pytest.mark.parametrize("bt,ht", [(1, 1), (1, 4), (2, 2), (4, 1), (0, 0)])
    def test_tile_invariance(self, bt, ht):
        """Every tile decomposition computes the identical convolution."""
        b, h, n = 4, 4, 256
        u, k = rand((b, h, n), 1), rand((h, n), 2)
        cfg = m2.Monarch2Config(seq_len=n, input_len=n, b_tile=bt, h_tile=ht)
        got = run_m2(cfg, u, k)
        want = np.array(ref.fft_conv(jnp.asarray(u), jnp.asarray(k)))
        np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)

    def test_tile_must_divide(self):
        cfg = m2.Monarch2Config(seq_len=64, input_len=64, b_tile=3, h_tile=1)
        fn = m2.build_conv_fn(cfg)
        ops = list(m2.kernel_operands(cfg, rand((4, 64), 0)).values()) + list(
            m2.constant_operands(cfg).values()
        )
        with pytest.raises(ValueError):
            fn(jnp.zeros((4, 4, 64)), *[jnp.asarray(o) for o in ops])

    def test_tiled_causal_gated(self):
        b, h, n = 2, 4, 128
        u, v, w = (rand((b, h, n), i) for i in range(3))
        k = rand((h, n), 9)
        cfg = m2.Monarch2Config(seq_len=2 * n, input_len=n, gated=True,
                                b_tile=1, h_tile=2)
        fn = m2.build_conv_fn(cfg)
        ops = list(m2.kernel_operands(cfg, k).values()) + list(
            m2.constant_operands(cfg).values()
        )
        got = np.array(
            fn(jnp.asarray(u), jnp.asarray(v), jnp.asarray(w),
               *[jnp.asarray(o) for o in ops])
        )
        want = np.array(
            ref.fft_conv_gated_causal(
                jnp.asarray(u), jnp.asarray(v), jnp.asarray(w), jnp.asarray(k)
            )
        )
        np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)

    def test_tiled_sparse_complex_path(self):
        b, h, n = 2, 4, 256
        u, k = rand((b, h, n), 5), rand((h, n), 6)
        cfg = m2.Monarch2Config(seq_len=n, input_len=n, r2c=False,
                                keep_rows=16, keep_cols=16, b_tile=1, h_tile=4)
        got = run_m2(cfg, u, k)
        want = np.array(ref.fft_conv(jnp.asarray(u), jnp.asarray(k)))
        np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


class TestOrder3Tiling:
    @pytest.mark.parametrize("bt,ht", [(1, 2), (2, 1), (0, 0)])
    def test_tile_invariance(self, bt, ht):
        b, h, n = 2, 2, 1024
        u, k = rand((b, h, n), 3), rand((h, n), 4)
        cfg = m3.Monarch3Config(seq_len=n, input_len=n, b_tile=bt, h_tile=ht)
        fn = m3.build_conv_fn(cfg)
        ops = list(m3.kernel_operands(cfg, k).values()) + list(
            m3.constant_operands(cfg).values()
        )
        got = np.array(fn(jnp.asarray(u), *[jnp.asarray(o) for o in ops]))
        want = np.array(ref.fft_conv(jnp.asarray(u), jnp.asarray(k)))
        np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)
