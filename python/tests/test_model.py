"""Model-level tests: shapes, loss behaviour, training descent, variants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


def tokens(cfg, batch, seed=0, extra=1):
    r = np.random.default_rng(seed)
    return jnp.asarray(
        r.integers(0, cfg.vocab, size=(batch, cfg.seq_len + extra)), dtype=jnp.int32
    )


TINY = M.ModelConfig(vocab=32, dim=16, layers=1, seq_len=64)


class TestShapes:
    def test_lm_forward_shape(self):
        p = M.init_params(TINY)
        t = tokens(TINY, 2, extra=0)
        logits = M.lm_forward(p, t, TINY)
        assert logits.shape == (2, 64, 32)

    def test_classifier_shape(self):
        cfg = M.ModelConfig(dim=16, layers=1, seq_len=128, mixer="longconv", n_classes=2)
        p = M.init_params(cfg)
        pix = jnp.zeros((3, 128))
        assert M.classifier_forward(p, pix, cfg).shape == (3, 2)

    def test_param_count_positive_and_scales(self):
        p1 = M.init_params(M.ModelConfig(vocab=32, dim=16, layers=1, seq_len=64))
        p2 = M.init_params(M.ModelConfig(vocab=32, dim=32, layers=2, seq_len=64))
        assert M.ModelConfig.param_count(p2) > 2 * M.ModelConfig.param_count(p1)

    def test_flatten_roundtrip(self):
        p = M.init_params(TINY)
        names, leaves = M.flatten_params(p)
        assert names == sorted(names)
        q = M.unflatten_params(names, leaves)
        assert set(q) == set(p)
        for n in names:
            assert q[n].shape == p[n].shape


class TestLoss:
    def test_initial_loss_near_uniform(self):
        p = M.init_params(TINY)
        loss = float(M.lm_loss(p, tokens(TINY, 2), TINY))
        assert abs(loss - np.log(TINY.vocab)) < 0.5

    def test_monarch_and_baseline_agree(self):
        cfg_b = M.ModelConfig(**{**TINY.__dict__, "conv_impl": "baseline"})
        p = M.init_params(TINY)
        t = tokens(TINY, 2)
        lm = float(M.lm_loss(p, t, TINY))
        lb = float(M.lm_loss(p, t, cfg_b))
        assert abs(lm - lb) < 1e-3

    def test_full_kmask_is_identity(self):
        p = M.init_params(TINY)
        t = tokens(TINY, 2)
        l1 = float(M.lm_loss(p, t, TINY))
        l2 = float(M.lm_loss(p, t, TINY, jnp.ones(TINY.seq_len)))
        assert abs(l1 - l2) < 1e-4

    def test_kmask_truncation_changes_loss_smoothly(self):
        p = M.init_params(TINY)
        t = tokens(TINY, 2)
        full = float(M.lm_loss(p, t, TINY))
        half = jnp.concatenate([jnp.ones(32), jnp.zeros(32)])
        lh = float(M.lm_loss(p, t, TINY, half))
        assert np.isfinite(lh) and abs(lh - full) < 1.0

    def test_dense_sparse_block_matches_dense(self):
        from compile.kernels import fftmats as fm

        factors = fm.monarch_factors(TINY.seq_len, 2)
        cfg_s = M.ModelConfig(**{**TINY.__dict__, "sparse_block": factors})
        p = M.init_params(TINY)
        t = tokens(TINY, 2)
        assert abs(float(M.lm_loss(p, t, TINY)) - float(M.lm_loss(p, t, cfg_s))) < 1e-3

    def test_partial_filter_len_config(self):
        cfg = M.ModelConfig(vocab=32, dim=16, layers=1, seq_len=64, filter_len=16)
        p = M.init_params(cfg)
        assert p["layer0.fw3"].shape == (cfg.filter_hidden, cfg.dim)
        loss = float(M.lm_loss(p, tokens(cfg, 2), cfg))
        assert np.isfinite(loss)


class TestTraining:
    def _descend(self, cfg, steps=6):
        opt = M.AdamConfig(lr=3e-3)
        ts = jax.jit(M.make_train_step(cfg, opt))
        p = M.init_params(cfg)
        m = {k: jnp.zeros_like(v) for k, v in p.items()}
        v = {k: jnp.zeros_like(x) for k, x in p.items()}
        step = jnp.asarray(0.0)
        rng = np.random.default_rng(1)
        losses = []
        for _ in range(steps):
            start = rng.integers(0, cfg.vocab)
            row = (start + np.arange(cfg.seq_len + 1)) % cfg.vocab
            batch = jnp.asarray(np.stack([row, row]), dtype=jnp.int32)
            p, m, v, step, loss = ts(p, m, v, step, batch)
            losses.append(float(loss))
        return losses

    def test_hyena_loss_descends(self):
        losses = self._descend(TINY)
        assert losses[-1] < losses[0]
        assert all(np.isfinite(l) for l in losses)

    def test_attention_loss_descends(self):
        cfg = M.ModelConfig(vocab=32, dim=16, layers=1, seq_len=64, mixer="attention", heads=2)
        losses = self._descend(cfg)
        assert losses[-1] < losses[0]

    def test_classifier_trains(self):
        cfg = M.ModelConfig(dim=16, layers=1, seq_len=64, mixer="longconv")
        opt = M.AdamConfig(lr=3e-3)
        ts = jax.jit(M.make_classifier_train_step(cfg, opt))
        p = M.init_params(cfg)
        m = {k: jnp.zeros_like(x) for k, x in p.items()}
        v = {k: jnp.zeros_like(x) for k, x in p.items()}
        step = jnp.asarray(0.0)
        rng = np.random.default_rng(2)
        losses = []
        for _ in range(6):
            # separable synthetic task: label = sign of mean pixel
            pix = rng.normal(size=(4, 64)).astype(np.float32) + rng.choice([-1, 1], size=(4, 1))
            lab = (pix.mean(axis=1) > 0).astype(np.int32)
            p, m, v, step, loss = ts(p, m, v, step, jnp.asarray(pix), jnp.asarray(lab))
            losses.append(float(loss))
        assert losses[-1] < losses[0]

    def test_adam_step_moves_params(self):
        p = M.init_params(TINY)
        g = {k: jnp.ones_like(v) * 0.1 for k, v in p.items()}
        m = {k: jnp.zeros_like(v) for k, v in p.items()}
        v = {k: jnp.zeros_like(x) for k, x in p.items()}
        p2, m2, v2 = M.adam_step(p, m, v, jnp.asarray(1.0), g, M.AdamConfig())
        assert float(jnp.abs(p2["embed"] - p["embed"]).max()) > 0
        assert float(jnp.abs(m2["embed"]).max()) > 0

    def test_grad_clip_bounds_update(self):
        opt = M.AdamConfig(lr=1.0, grad_clip=1e-6)
        p = M.init_params(TINY)
        g = {k: jnp.ones_like(v) * 1e3 for k, v in p.items()}
        m = {k: jnp.zeros_like(v) for k, v in p.items()}
        v = {k: jnp.zeros_like(x) for k, x in p.items()}
        p2, _, _ = M.adam_step(p, m, v, jnp.asarray(1.0), g, opt)
        # clipped grads are tiny, but adam normalizes m/sqrt(v): update ~ lr.
        assert float(jnp.abs(p2["embed"] - p["embed"]).max()) <= 1.001 * opt.lr


class TestFilters:
    def test_positional_features_shape(self):
        f = M.positional_features(128, 9)
        assert f.shape == (128, 9)

    def test_decay_window_monotone(self):
        w = np.array(M.decay_window(64, 4))
        assert np.all(np.diff(w, axis=1) <= 1e-9)
        assert np.all(w > 0) and np.all(w <= 1.0)

    def test_hyena_filter_shape(self):
        p = M.init_params(TINY)
        k = M.hyena_filter(p, "layer0", TINY)
        assert k.shape == (TINY.dim, TINY.seq_len)
