"""Tests for the analytic TPU roofline estimator (§Perf, L1)."""

import pytest

from compile.kernels import roofline as rl


class TestGemmShape:
    def test_flops(self):
        g = rl.GemmShape(64, 32, 128, 3)
        assert g.flops == 2 * 64 * 32 * 128 * 3

    def test_fill_saturates(self):
        big = rl.GemmShape(1024, 1024, 1024)
        assert big.mxu_fill > 0.85
        small = rl.GemmShape(8, 8, 8)
        assert small.mxu_fill < 0.01

    def test_fill_monotone_in_n(self):
        fills = [rl.GemmShape(64, 64, n).mxu_fill for n in (16, 64, 256, 1024)]
        assert fills == sorted(fills)


class TestEstimates:
    @pytest.mark.parametrize("n", [256, 1024, 4096, 16384])
    def test_shipped_tiles_fit_vmem(self, n):
        assert rl.order2_estimate(n, 32).fits_vmem

    def test_utilization_improves_with_tile(self):
        """The B_tile/H_tile batching exists precisely to raise MXU fill."""
        u1 = rl.order2_estimate(4096, 1).mxu_utilization
        u32 = rl.order2_estimate(4096, 32).mxu_utilization
        assert u32 > 2 * u1, f"{u1} -> {u32}"

    def test_utilization_improves_with_length(self):
        u_short = rl.order2_estimate(256, 32).mxu_utilization
        u_long = rl.order2_estimate(16384, 32).mxu_utilization
        assert u_long > u_short

    def test_utilization_band_at_16k(self):
        """With the shipped tiles the 16K kernel sustains a meaningful
        fraction of the MXU (the paper's utilization story scales further
        with its much larger B*H=49152 tiles and bf16 operands — the
        estimator is deliberately conservative; DESIGN.md §Perf)."""
        est = rl.order2_estimate(16384, 32)
        assert est.mxu_utilization >= 0.3, est

    def test_order3_fits_with_fitted_tile(self):
        tile = rl.max_tile_for_vmem(65536, 3)
        est = rl.order3_estimate(65536, tile)
        assert est.fits_vmem
        assert tile >= 2

    def test_vmem_grows_linearly_with_tile(self):
        a = rl.order2_estimate(4096, 8).vmem_bytes
        b = rl.order2_estimate(4096, 32).vmem_bytes
        assert 2.5 < b / a < 4.5

    def test_max_tile_monotone_decreasing_in_n(self):
        tiles = [rl.max_tile_for_vmem(n, 2) for n in (4096, 16384, 65536)]
        assert tiles == sorted(tiles, reverse=True)
        assert tiles[0] >= 32

    def test_report_renders(self):
        r = rl.report()
        assert "MXU_util" in r and "order3" in r
