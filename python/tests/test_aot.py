"""Artifact-builder tests: manifest grammar, fixtures, goldens, HLO text."""

import os

import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import model as M


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    """Build a tiny artifact set once for the whole module."""
    out = str(tmp_path_factory.mktemp("art"))
    b = aot.ArtifactBuilder(out, verbose=False)

    def fn(x, y):
        return (x @ y + 1.0, jnp.sum(x))

    b.add(
        "tiny_matmul",
        fn,
        [
            aot.InputSpec("x", np.ones((2, 3), np.float32), "runtime"),
            aot.InputSpec("y", np.full((3, 4), 2.0, np.float32), "const"),
        ],
        meta=dict(group="test", kind="demo", seq_len=4),
        output_names=["z", "s"],
        golden=True,
    )
    b.finish()
    return out


class TestManifest:
    def test_files_exist(self, built):
        for f in ["manifest.txt", "tiny_matmul.hlo.txt", "tiny_matmul.fix.bin",
                  "tiny_matmul.golden.bin"]:
            assert os.path.exists(os.path.join(built, f)), f

    def test_manifest_grammar(self, built):
        text = open(os.path.join(built, "manifest.txt")).read()
        assert text.startswith("version 1")
        assert "artifact tiny_matmul" in text
        assert "input x f32 2,3 runtime" in text
        assert "input y f32 3,4 const tiny_matmul.fix.bin 0" in text
        assert "output z f32 2,4" in text
        assert "output s f32 -" in text  # scalar shape token
        assert text.rstrip().split("\n").count("end") == 1

    def test_fixture_bytes(self, built):
        raw = open(os.path.join(built, "tiny_matmul.fix.bin"), "rb").read()
        y = np.frombuffer(raw, dtype=np.float32).reshape(3, 4)
        np.testing.assert_array_equal(y, np.full((3, 4), 2.0))

    def test_golden_layout(self, built):
        raw = open(os.path.join(built, "tiny_matmul.golden.bin"), "rb").read()
        # runtime input (2*3) + out z (2*4) + out s (1), all f32.
        assert len(raw) == (6 + 8 + 1) * 4
        vals = np.frombuffer(raw, dtype=np.float32)
        np.testing.assert_array_equal(vals[:6], np.ones(6))
        np.testing.assert_allclose(vals[6:14], np.full(8, 7.0))  # 1*2*3 + 1
        assert vals[14] == 6.0

    def test_hlo_text_has_full_constants(self, built):
        """Large constants must never be elided (the {...} trap)."""
        hlo = open(os.path.join(built, "tiny_matmul.hlo.txt")).read()
        assert "{...}" not in hlo
        assert "ENTRY" in hlo

    def test_hlo_has_no_new_metadata_attrs(self, built):
        hlo = open(os.path.join(built, "tiny_matmul.hlo.txt")).read()
        assert "source_end_line" not in hlo


class TestHelpers:
    def test_shape_str(self):
        assert aot._shape_str(()) == "-"
        assert aot._shape_str((2, 3)) == "2,3"

    def test_dtype_names(self):
        assert aot._dtype_name(np.float32) == "f32"
        assert aot._dtype_name(np.int32) == "i32"
        with pytest.raises(KeyError):
            aot._dtype_name(np.float64)

    def test_input_spec_validates_kind(self):
        with pytest.raises(AssertionError):
            aot.InputSpec("x", np.zeros(1, np.float32), "bogus")

    def test_state_output_names_roundtrip(self):
        names = ["a", "b"]
        out = aot._state_output_names(names)
        assert out == ["param.a", "param.b", "adam_m.a", "adam_m.b",
                       "adam_v.a", "adam_v.b", "step"]

    def test_flat_train_fn_shapes(self):
        cfg = M.ModelConfig(vocab=16, dim=8, layers=1, seq_len=32)
        opt = M.AdamConfig()
        params = M.init_params(cfg)
        names, leaves = M.flatten_params(params)
        fn = aot._flat_train_fn(cfg, opt, names)
        zeros = [jnp.zeros_like(l) for l in leaves]
        tok = jnp.zeros((2, 33), dtype=jnp.int32)
        outs = fn(*leaves, *zeros, *zeros, jnp.asarray(0.0), tok)
        assert len(outs) == 3 * len(names) + 2
        assert outs[-1].shape == ()  # loss scalar


class TestMonarchPermute:
    def test_matches_order_permutation(self):
        from compile.kernels import conv_op, fftmats as fm

        for factors in [(4, 8), (16, 16), (8, 8, 8), (2, 4, 2, 4)]:
            n = int(np.prod(factors))
            x = jnp.asarray(np.random.default_rng(0).normal(size=(3, n)).astype(np.float32))
            got = np.array(conv_op.monarch_permute(x, factors))
            want = np.array(x)[:, fm.monarch_order(factors)]
            np.testing.assert_array_equal(got, want)
