"""Unit tests for the build-time FFT matrix machinery (fftmats.py)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fftmats as fm

RNG = np.random.default_rng(0)


class TestFactorization:
    def test_is_pow2(self):
        assert fm.is_pow2(1) and fm.is_pow2(2) and fm.is_pow2(4096)
        assert not fm.is_pow2(0) and not fm.is_pow2(3) and not fm.is_pow2(-4)

    def test_balanced_factors(self):
        assert fm.monarch_factors(4096, 2) == (64, 64)
        assert fm.monarch_factors(8192, 2) == (128, 64)
        assert fm.monarch_factors(4096, 3) == (16, 16, 16)
        assert fm.monarch_factors(32768, 3) == (32, 32, 32)

    def test_factors_product(self):
        for logn in range(2, 22):
            for order in (2, 3, 4):
                if order > logn:
                    continue
                f = fm.monarch_factors(1 << logn, order)
                assert int(np.prod(f)) == 1 << logn
                assert len(f) == order
                # balanced: factors within 2x of each other
                assert max(f) <= 2 * min(f)

    def test_rejects_non_pow2(self):
        with pytest.raises(ValueError):
            fm.monarch_factors(100, 2)

    def test_rejects_over_split(self):
        with pytest.raises(ValueError):
            fm.monarch_factors(4, 5)


class TestDftMatrix:
    @pytest.mark.parametrize("n", [1, 2, 4, 8, 32])
    def test_matches_numpy_fft(self, n):
        x = RNG.normal(size=n) + 1j * RNG.normal(size=n)
        assert np.allclose(fm.dft_matrix(n) @ x, np.fft.fft(x))

    @pytest.mark.parametrize("n", [2, 8, 16])
    def test_inverse_roundtrip(self, n):
        assert np.allclose(
            fm.dft_matrix(n, inverse=True) @ fm.dft_matrix(n), np.eye(n), atol=1e-10
        )

    def test_twiddle_unit_modulus(self):
        t = fm.twiddle_grid(8, 4)
        assert np.allclose(np.abs(t), 1.0)
        assert np.allclose(t * fm.twiddle_grid(8, 4, inverse=True), 1.0)


class TestMonarchRef:
    @pytest.mark.parametrize(
        "factors",
        [(8,), (4, 8), (8, 4), (16, 16), (4, 4, 4), (2, 4, 8), (4, 4, 2, 4)],
    )
    def test_fwd_is_permuted_fft(self, factors):
        n = int(np.prod(factors))
        x = RNG.normal(size=(3, n)) + 1j * RNG.normal(size=(3, n))
        got = fm.monarch_fft_ref(x, factors)
        want = np.fft.fft(x, axis=-1)[:, fm.monarch_order(factors)]
        assert np.allclose(got, want)

    @pytest.mark.parametrize("factors", [(4, 8), (16, 16), (4, 4, 4), (2, 2, 2, 2)])
    def test_inverse_roundtrip(self, factors):
        n = int(np.prod(factors))
        x = RNG.normal(size=n) + 1j * RNG.normal(size=n)
        assert np.allclose(fm.monarch_ifft_ref(fm.monarch_fft_ref(x, factors), factors), x)

    @pytest.mark.parametrize("factors", [(4, 8), (8, 8), (4, 4, 4)])
    def test_order_is_permutation(self, factors):
        order = fm.monarch_order(factors)
        n = int(np.prod(factors))
        assert sorted(order.tolist()) == list(range(n))

    @pytest.mark.parametrize("factors", [(4, 8), (8, 8), (4, 4, 4)])
    def test_neg_freq_perm(self, factors):
        order = fm.monarch_order(factors)
        neg = fm.neg_freq_perm(factors)
        m = len(order)
        # layout_freq(neg[j]) == -layout_freq(j) mod m, and it's an involution
        assert np.array_equal(order[neg], (-order) % m)
        assert np.array_equal(neg[neg], np.arange(m))

    def test_length_mismatch_raises(self):
        with pytest.raises(ValueError):
            fm.monarch_fft_ref(np.zeros(7, dtype=complex), (2, 4))


class TestConvIdentity:
    @settings(max_examples=12, deadline=None)
    @given(
        logn=st.integers(min_value=3, max_value=9),
        seed=st.integers(min_value=0, max_value=2**31),
        order=st.integers(min_value=2, max_value=3),
    )
    def test_conv_through_monarch_layout(self, logn, seed, order):
        """Permuted spectra still convolve exactly (conv theorem is P-invariant)."""
        n = 1 << logn
        if order > logn:
            return
        factors = fm.monarch_factors(n, order)
        r = np.random.default_rng(seed)
        u, k = r.normal(size=n), r.normal(size=n)
        kf_mon = fm.kf_monarch(k, factors)
        y = fm.monarch_ifft_ref(
            fm.monarch_fft_ref(u.astype(complex), factors) * kf_mon, factors
        )
        want = np.fft.ifft(np.fft.fft(u) * np.fft.fft(k))
        assert np.allclose(y, want)


class TestR2cPacking:
    @settings(max_examples=12, deadline=None)
    @given(logn=st.integers(min_value=3, max_value=10), seed=st.integers(0, 2**31))
    def test_packed_conv_equals_real_conv(self, logn, seed):
        n = 1 << logn
        fh = fm.monarch_factors(n // 2, 2) if logn >= 4 else (n // 2,)
        r = np.random.default_rng(seed)
        u, k = r.normal(size=n), r.normal(size=n)
        a_mon, b_mon, negp = fm.kf_r2c_monarch(k, fh)
        z = u[0::2] + 1j * u[1::2]
        zmon = fm.monarch_fft_ref(z, fh)
        zy = a_mon * zmon + b_mon * np.conj(zmon[negp])
        zt = fm.monarch_ifft_ref(zy, fh)
        y = np.empty(n)
        y[0::2], y[1::2] = zt.real, zt.imag
        want = np.fft.ifft(np.fft.fft(u) * np.fft.fft(k)).real
        assert np.allclose(y, want)

    def test_multihead_kernels(self):
        n, h = 64, 4
        k = RNG.normal(size=(h, n))
        a, b, negp = fm.kf_r2c_monarch(k, (8, 4))
        assert a.shape == (h, n // 2) and b.shape == (h, n // 2)
        assert negp.shape == (n // 2,)

    def test_odd_length_rejected(self):
        with pytest.raises(ValueError):
            fm.r2c_pointwise_coeffs(np.zeros(7, dtype=complex))


class TestSparsityPatterns:
    def test_fraction_math(self):
        p = fm.SparsityPattern(32, 32, 16, 32)
        assert abs(p.sparsity_fraction - 0.5) < 1e-12
        p = fm.SparsityPattern(32, 32, 16, 16)
        assert abs(p.sparsity_fraction - 0.75) < 1e-12

    def test_flop_fraction_bounds(self):
        for p in fm.table10_patterns(32, 32).values():
            assert 0.0 < p.matmul_flop_fraction <= 1.0
        dense = fm.SparsityPattern(32, 32, 32, 32)
        assert abs(dense.matmul_flop_fraction - 1.0) < 1e-12

    def test_flop_fraction_monotone_in_sparsity(self):
        pats = sorted(
            fm.table10_patterns(32, 32).values(), key=lambda p: p.sparsity_fraction
        )
        fracs = [p.matmul_flop_fraction for p in pats]
        assert fracs == sorted(fracs, reverse=True)

    def test_apply_zeroes_expected_entries(self):
        p = fm.SparsityPattern(4, 4, 2, 3)
        kf = np.ones(16, dtype=complex)
        out = p.apply(kf).reshape(4, 4)
        assert np.all(out[2:, :] == 0) and np.all(out[:, 3:] == 0)
        assert np.all(out[:2, :3] == 1)

    def test_validation(self):
        with pytest.raises(ValueError):
            fm.SparsityPattern(4, 4, 0, 4)
        with pytest.raises(ValueError):
            fm.SparsityPattern(4, 4, 2, 5)
        with pytest.raises(ValueError):
            fm.SparsityPattern(4, 4, 4, 4).apply(np.ones(8, dtype=complex))

    def test_table10_fractions_match_paper_ladder(self):
        pats = fm.table10_patterns(32, 32)
        assert abs(pats["s0"].sparsity_fraction - 0.0) < 1e-9
        assert abs(pats["s50"].sparsity_fraction - 0.5) < 1e-9
        assert abs(pats["s75"].sparsity_fraction - 0.75) < 1e-9
        assert pats["s84"].sparsity_fraction > 0.8
        assert pats["s91"].sparsity_fraction > 0.9
