"""Pallas kernel vs pure-jnp oracle: the core correctness signal.

Hypothesis sweeps shapes and dtypes across every kernel variant; each
assertion compares the fused Monarch kernel against the `ref.py` oracle
(`jnp.fft`-based), which is itself pinned against the O(N^2) definition.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import monarch2 as m2
from compile.kernels import monarch3 as m3
from compile.kernels import ref

TOL = dict(rtol=2e-3, atol=2e-3)


def rand(shape, seed, dtype=np.float32):
    return np.random.default_rng(seed).normal(size=shape).astype(dtype)


class TestOracleSelfConsistency:
    """ref.fft_conv is pinned against the O(N^2) definition first."""

    @pytest.mark.parametrize("n", [8, 32, 64])
    def test_fft_conv_vs_direct(self, n):
        u, k = rand((2, 3, n), 0), rand((3, n), 1)
        got = ref.fft_conv(jnp.asarray(u), jnp.asarray(k))
        want = ref.direct_conv(jnp.asarray(u), jnp.asarray(k))
        np.testing.assert_allclose(np.array(got), np.array(want), rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("n", [8, 32])
    def test_fft_conv_causal_vs_direct(self, n):
        u, k = rand((2, 2, n), 2), rand((2, n), 3)
        got = ref.fft_conv_causal(jnp.asarray(u), jnp.asarray(k))
        want = ref.direct_causal_conv(jnp.asarray(u), jnp.asarray(k))
        np.testing.assert_allclose(np.array(got), np.array(want), rtol=1e-4, atol=1e-4)

    def test_causality(self):
        """Causal conv output at i must not depend on inputs after i."""
        n = 64
        u1, k = rand((1, 1, n), 4), rand((1, n), 5)
        u2 = u1.copy()
        u2[..., n // 2 :] += 100.0
        y1 = np.array(ref.fft_conv_causal(jnp.asarray(u1), jnp.asarray(k)))
        y2 = np.array(ref.fft_conv_causal(jnp.asarray(u2), jnp.asarray(k)))
        np.testing.assert_allclose(y1[..., : n // 2], y2[..., : n // 2], rtol=1e-4, atol=1e-4)


class TestMonarch2Kernel:
    @settings(max_examples=10, deadline=None)
    @given(
        logn=st.integers(min_value=4, max_value=11),
        b=st.integers(min_value=1, max_value=3),
        h=st.integers(min_value=1, max_value=4),
        seed=st.integers(0, 2**31),
    )
    def test_r2c_circular(self, logn, b, h, seed):
        n = 1 << logn
        u, k = rand((b, h, n), seed), rand((h, n), seed + 1)
        got = np.array(m2.conv_r2c(u, k))
        want = np.array(ref.fft_conv(jnp.asarray(u), jnp.asarray(k)))
        np.testing.assert_allclose(got, want, **TOL)

    @settings(max_examples=8, deadline=None)
    @given(logn=st.integers(min_value=4, max_value=11), seed=st.integers(0, 2**31))
    def test_r2c_causal(self, logn, seed):
        n = 1 << logn
        u, k = rand((2, 2, n), seed), rand((2, n), seed + 1)
        got = np.array(m2.conv_r2c(u, k, causal=True))
        want = np.array(ref.fft_conv_causal(jnp.asarray(u), jnp.asarray(k)))
        np.testing.assert_allclose(got, want, **TOL)

    @settings(max_examples=6, deadline=None)
    @given(logn=st.integers(min_value=4, max_value=10), seed=st.integers(0, 2**31))
    def test_gated(self, logn, seed):
        n = 1 << logn
        u, v, w = (rand((2, 2, n), seed + i) for i in range(3))
        k = rand((2, n), seed + 9)
        got = np.array(m2.conv_r2c_gated(u, v, w, k))
        want = np.array(
            ref.fft_conv_gated(jnp.asarray(u), jnp.asarray(v), jnp.asarray(w), jnp.asarray(k))
        )
        np.testing.assert_allclose(got, want, **TOL)

    @settings(max_examples=6, deadline=None)
    @given(logn=st.integers(min_value=4, max_value=10), seed=st.integers(0, 2**31))
    def test_gated_causal(self, logn, seed):
        n = 1 << logn
        u, v, w = (rand((2, 2, n), seed + i) for i in range(3))
        k = rand((2, n), seed + 9)
        got = np.array(m2.conv_r2c_gated(u, v, w, k, causal=True))
        want = np.array(
            ref.fft_conv_gated_causal(
                jnp.asarray(u), jnp.asarray(v), jnp.asarray(w), jnp.asarray(k)
            )
        )
        np.testing.assert_allclose(got, want, **TOL)

    @pytest.mark.parametrize("karatsuba", [True, False])
    def test_complex_path_ablation(self, karatsuba):
        """The no-domain-opts ablation row must also be exact."""
        n = 256
        u, k = rand((2, 3, n), 7), rand((3, n), 8)
        got = np.array(m2.conv_basic(u, k, karatsuba=karatsuba))
        want = np.array(ref.fft_conv(jnp.asarray(u), jnp.asarray(k)))
        np.testing.assert_allclose(got, want, **TOL)

    def test_rectangular_factors(self):
        """Non-square N1 != N2 splits (e.g. N=2048 -> M=1024=32x32, N=512 -> M=256=16x16,
        N=8192 -> M=4096... pick N=2^odd so M has uneven split)."""
        n = 512  # M=256 -> (16,16); also test n=2048 -> M=1024 (32,32) and n=128 -> M=64 (8,8)
        for n in (128, 512, 2048):
            u, k = rand((1, 2, n), n), rand((2, n), n + 1)
            got = np.array(m2.conv_r2c(u, k))
            want = np.array(ref.fft_conv(jnp.asarray(u), jnp.asarray(k)))
            np.testing.assert_allclose(got, want, **TOL)

    def test_bf16_inputs(self):
        n = 256
        u = rand((2, 2, n), 11).astype(jnp.bfloat16)
        k = rand((2, n), 12)
        got = np.array(m2.conv_r2c(np.asarray(u), k).astype(jnp.float32))
        want = np.array(ref.fft_conv(jnp.asarray(u, dtype=jnp.float32), jnp.asarray(k)))
        np.testing.assert_allclose(got, want, rtol=5e-2, atol=5e-1)

    def test_input_length_mismatch_raises(self):
        cfg = m2.Monarch2Config(seq_len=64, input_len=64)
        fn = m2.build_conv_fn(cfg)
        with pytest.raises(ValueError):
            fn(jnp.zeros((1, 1, 32)))

    def test_config_validation(self):
        with pytest.raises(ValueError):
            m2.Monarch2Config(seq_len=100, input_len=100)
        with pytest.raises(ValueError):
            m2.Monarch2Config(seq_len=64, input_len=16)
        with pytest.raises(ValueError):
            m2.Monarch2Config(seq_len=64, input_len=64, r2c=True, keep_rows=4, keep_cols=4)


class TestSparseKernel:
    @pytest.mark.parametrize("keep", [(16, 16), (8, 16), (16, 8), (8, 8), (4, 4)])
    def test_sparse_vs_sparsified_spectrum(self, keep):
        n = 256  # factors (16, 16)
        u, k = rand((2, 2, n), 20), rand((2, n), 21)
        y, kf_sp = m2.conv_sparse(u, k, *keep)
        want = np.array(ref.fft_conv_kf(jnp.asarray(u), jnp.asarray(kf_sp.astype(np.complex64))))
        np.testing.assert_allclose(np.array(y), want, **TOL)

    def test_dense_pattern_recovers_exact_conv(self):
        n = 256
        u, k = rand((1, 1, n), 22), rand((1, n), 23)
        y, _ = m2.conv_sparse(u, k, 16, 16)
        want = np.array(ref.fft_conv(jnp.asarray(u), jnp.asarray(k)))
        np.testing.assert_allclose(np.array(y), want, **TOL)

    def test_sparse_preserves_kept_frequencies_exactly(self):
        """Sparsification only *removes* frequencies: a pure tone whose
        frequency lies in the kept block convolves exactly as in the dense
        kernel (the (0, 0) layout slot — DC — is always kept)."""
        n = 256
        k = rand((1, n), 23)
        u = np.ones((1, 1, n), dtype=np.float32)  # pure DC input
        y_dense = np.array(m2.conv_r2c(u, k))
        y_sparse, _ = m2.conv_sparse(u, k, 4, 4)
        np.testing.assert_allclose(np.array(y_sparse), y_dense, **TOL)


class TestMonarch3Kernel:
    @settings(max_examples=6, deadline=None)
    @given(logn=st.integers(min_value=7, max_value=12), seed=st.integers(0, 2**31))
    def test_circular(self, logn, seed):
        n = 1 << logn
        u, k = rand((1, 2, n), seed), rand((2, n), seed + 1)
        got = np.array(m3.conv3_r2c(u, k))
        want = np.array(ref.fft_conv(jnp.asarray(u), jnp.asarray(k)))
        np.testing.assert_allclose(got, want, **TOL)

    @settings(max_examples=4, deadline=None)
    @given(logn=st.integers(min_value=7, max_value=12), seed=st.integers(0, 2**31))
    def test_causal(self, logn, seed):
        n = 1 << logn
        u, k = rand((1, 2, n), seed), rand((2, n), seed + 1)
        got = np.array(m3.conv3_r2c(u, k, causal=True))
        want = np.array(ref.fft_conv_causal(jnp.asarray(u), jnp.asarray(k)))
        np.testing.assert_allclose(got, want, **TOL)

    def test_gated_causal(self):
        n = 1024
        u, v, w = (rand((1, 2, n), 30 + i) for i in range(3))
        k = rand((2, n), 33)
        got = np.array(m3.conv3_r2c(u, k, causal=True, gated_vw=(v, w)))
        want = np.array(
            ref.fft_conv_gated_causal(
                jnp.asarray(u), jnp.asarray(v), jnp.asarray(w), jnp.asarray(k)
            )
        )
        np.testing.assert_allclose(got, want, **TOL)

    def test_order2_order3_agree(self):
        n = 2048
        u, k = rand((1, 1, n), 40), rand((1, n), 41)
        y2 = np.array(m2.conv_r2c(u, k))
        y3 = np.array(m3.conv3_r2c(u, k))
        np.testing.assert_allclose(y2, y3, **TOL)
