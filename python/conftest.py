"""Pytest path setup: make `compile.*` importable from any invocation dir
(`pytest python/tests/` from the repo root, or `pytest tests/` from
`python/`)."""

import os
import sys

sys.path.insert(0, os.path.dirname(__file__))
