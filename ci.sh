#!/usr/bin/env bash
# Tier-1 verification + lint gate for the FlashFFTConv reproduction.
#
# The first two steps are the tier-1 contract (ROADMAP.md) and must pass
# from a clean checkout with no network, no Python step, and no pre-built
# artifacts — the native backend self-generates its fleet.
#
# fmt/clippy run when the components are installed; set FFC_CI_LINT=strict
# to make their findings fatal (the default is advisory so the gate stays
# usable on minimal toolchains).
set -euo pipefail
cd "$(dirname "$0")"

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q"
cargo test -q

# The pjrt feature compiles against the vendored xla API stub offline;
# keep it building so backend-trait changes never strand the HLO path.
echo "==> cargo check --features pjrt"
cargo check --features pjrt

# Quickstart doubles as the public-API smoke test: golden replay + oracle
# check over the native backend from a clean checkout.
echo "==> cargo run --release --example quickstart"
cargo run --release --example quickstart

lint_mode="${FFC_CI_LINT:-advisory}"

if cargo fmt --version >/dev/null 2>&1; then
    echo "==> cargo fmt --check (${lint_mode})"
    if ! cargo fmt --check; then
        if [ "${lint_mode}" = "strict" ]; then
            exit 1
        fi
        echo "(fmt differences above are advisory; FFC_CI_LINT=strict to enforce)"
    fi
else
    echo "==> cargo fmt not installed; skipping"
fi

if cargo clippy --version >/dev/null 2>&1; then
    echo "==> cargo clippy -- -D warnings (${lint_mode})"
    if ! cargo clippy --all-targets -- -D warnings; then
        if [ "${lint_mode}" = "strict" ]; then
            exit 1
        fi
        echo "(clippy findings above are advisory; FFC_CI_LINT=strict to enforce)"
    fi
else
    echo "==> cargo clippy not installed; skipping"
fi

echo "==> ci.sh OK"
