#!/usr/bin/env bash
# Tier-1 verification + lint gate for the FlashFFTConv reproduction.
#
# The first two steps are the tier-1 contract (ROADMAP.md) and must pass
# from a clean checkout with no network, no Python step, and no pre-built
# artifacts — the native backend self-generates its fleet.
#
# fmt/clippy run when the components are installed; set FFC_CI_LINT=strict
# to make their findings fatal (the default is advisory so the gate stays
# usable on minimal toolchains).
set -euo pipefail
cd "$(dirname "$0")"

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q"
cargo test -q

# Same suite with the portable kernel tier pinned: every SIMD microkernel
# has a scalar twin, and the whole tree must pass on it — this is what a
# host without AVX2/FMA (or a miscompiled target-feature gate) would run.
echo "==> cargo test -q (FFC_FORCE_SCALAR=1, portable kernel tier)"
FFC_FORCE_SCALAR=1 cargo test -q

# The pjrt feature compiles against the vendored xla API stub offline;
# keep it building so backend-trait changes never strand the HLO path.
echo "==> cargo check --features pjrt"
cargo check --features pjrt

# Quickstart doubles as the public-API smoke test: golden replay + oracle
# check over the native backend from a clean checkout.
echo "==> cargo run --release --example quickstart"
cargo run --release --example quickstart

# Fleet soak under an explicit wall-clock bound: the sharded-dispatcher
# test suite (concurrent clients, backpressure, shard-death respawn) must
# converge — a hang here is a supervision bug, not a slow box.
echo "==> fleet soak: cargo test --test fleet_e2e (bounded)"
if command -v timeout >/dev/null 2>&1; then
    timeout 900 cargo test -q --test fleet_e2e
else
    cargo test -q --test fleet_e2e
fi

# Fleet perf artifact: a small soak through the bench must emit
# BENCH_fleet.json with both the single-worker and the sharded records so
# the fleet-vs-single trajectory accumulates across PRs.
echo "==> fleet perf smoke: cargo bench --bench table5_fleet"
rm -f BENCH_fleet.json
FFC_FLEET_REQUESTS=160 FFC_FLEET_CLIENTS=4 cargo bench --bench table5_fleet >/dev/null
test -s BENCH_fleet.json || { echo "FAIL: BENCH_fleet.json missing or empty"; exit 1; }
if command -v python3 >/dev/null 2>&1; then
    python3 - <<'PY'
import json
recs = json.load(open("BENCH_fleet.json"))
by_name = {r["name"]: r for r in recs}
single = by_name.get("serve_conv_single")
fleet = by_name.get("serve_conv_fleet")
assert single and fleet, f"missing fleet records: {sorted(by_name)}"
for r in (single, fleet):
    missing = {"name", "n", "mean_ns", "median_ns", "p95_ns"} - set(r)
    assert not missing, f"record missing {missing}: {r}"
    assert r["n"] > 0 and r["median_ns"] > 0, f"degenerate record: {r}"
speedup = single["median_ns"] / fleet["median_ns"]
print(f"BENCH_fleet.json OK (fleet vs single-worker rows/sec: {speedup:.2f}x)")
if speedup <= 1.0:
    print(f"WARN: fleet did not beat the single worker this run ({speedup:.2f}x)")
PY
else
    grep -q '"serve_conv_fleet"' BENCH_fleet.json \
        && grep -q '"serve_conv_single"' BENCH_fleet.json \
        && echo "BENCH_fleet.json OK (grep check; python3 unavailable)"
fi

# Ingress soak under an explicit wall-clock bound: the wire codec
# property suite plus the loopback TCP end-to-end suite (concurrent wire
# clients, parity vs in-process, drain + filter swaps mid-soak, session
# reaping) must converge — a hang here is a connection-pool or
# FIFO-writer bug, not a slow box.
echo "==> ingress soak: cargo test --test ingress_wire --test ingress_e2e (bounded)"
if command -v timeout >/dev/null 2>&1; then
    timeout 900 cargo test -q --test ingress_wire
    timeout 900 cargo test -q --test ingress_e2e
else
    cargo test -q --test ingress_wire
    cargo test -q --test ingress_e2e
fi

# Fault injection under an explicit wall-clock bound: the chaos suite
# (slow-loris eviction, dribbled/cut/stalled frames through the chaos
# proxy, quota sheds, reply deadlines, streamed-reply teardown, shard
# poison mid-soak) must surface every failure as a typed status and
# converge — a hang here IS the bug the suite exists to catch. The CLI
# integration test then drives the real compiled `serve --listen` binary
# through spawn/handshake/wire traffic/stdin-EOF drain.
echo "==> fault injection: cargo test --test ingress_chaos --test serve_listen_cli (bounded)"
if command -v timeout >/dev/null 2>&1; then
    timeout 900 cargo test -q --test ingress_chaos
    timeout 900 cargo test -q --test serve_listen_cli
else
    cargo test -q --test ingress_chaos
    cargo test -q --test serve_listen_cli
fi

# Ingress perf artifact: a small loopback soak through the bench must
# emit BENCH_ingress.json with the paired 1-shard/N-shard records (and
# the swap-racing row) so the network-front trajectory accumulates
# across PRs.
echo "==> ingress perf smoke: cargo bench --bench table_ingress"
rm -f BENCH_ingress.json
FFC_INGRESS_REQUESTS=96 FFC_INGRESS_CLIENTS=4 cargo bench --bench table_ingress >/dev/null
test -s BENCH_ingress.json || { echo "FAIL: BENCH_ingress.json missing or empty"; exit 1; }
if command -v python3 >/dev/null 2>&1; then
    python3 - <<'PY'
import json
recs = json.load(open("BENCH_ingress.json"))
by_name = {r["name"]: r for r in recs}
single = by_name.get("ingress_1shard")
fleet = by_name.get("ingress_fleet")
swap = by_name.get("ingress_fleet_swap")
assert single and fleet, f"missing paired ingress records: {sorted(by_name)}"
assert swap, f"missing swap-racing ingress record: {sorted(by_name)}"
for r in (single, fleet, swap):
    missing = {"name", "shards", "rows", "rows_per_sec", "p50_ms", "p99_ms"} - set(r)
    assert not missing, f"record missing {missing}: {r}"
    assert r["rows"] > 0 and r["rows_per_sec"] > 0, f"degenerate record: {r}"
    assert r["p99_ms"] >= r["p50_ms"] > 0, f"bad percentiles: {r}"
assert single["shards"] == 1 and fleet["shards"] > 1, \
    f"records not paired 1-shard/N-shard: {single} {fleet}"
assert swap["swaps"] > 0, f"swap row recorded no filter installs: {swap}"
speedup = fleet["rows_per_sec"] / single["rows_per_sec"]
print(f"BENCH_ingress.json OK (fleet vs 1-shard over the wire: {speedup:.2f}x; "
      f"p99 {fleet['p99_ms']:.2f} ms plain vs {swap['p99_ms']:.2f} ms under swaps)")
if speedup <= 1.0:
    print(f"WARN: fleet did not beat one shard over the wire this run ({speedup:.2f}x)")
PY
else
    grep -q '"ingress_1shard"' BENCH_ingress.json \
        && grep -q '"ingress_fleet"' BENCH_ingress.json \
        && grep -q '"p99_ms"' BENCH_ingress.json \
        && echo "BENCH_ingress.json OK (grep check; python3 unavailable)"
fi

# Streamed-reply perf artifact: the wire-v2 chunked reply path vs the
# single-frame baseline at two payload sizes must land in
# BENCH_ingress_stream.json so the streaming overhead stays visible
# across PRs (both modes present per size, sane percentiles).
echo "==> ingress stream smoke: cargo bench --bench table_ingress_stream"
rm -f BENCH_ingress_stream.json
FFC_STREAM_REQUESTS=32 cargo bench --bench table_ingress_stream >/dev/null
test -s BENCH_ingress_stream.json \
    || { echo "FAIL: BENCH_ingress_stream.json missing or empty"; exit 1; }
if command -v python3 >/dev/null 2>&1; then
    python3 - <<'PY'
import json
recs = json.load(open("BENCH_ingress_stream.json"))
by = {r["name"]: r for r in recs}
lens = sorted({r["len"] for r in recs})
assert len(lens) >= 2, f"need >=2 payload sizes, got {lens}"
for n in lens:
    single = by.get(f"single_{n}")
    streamed = by.get(f"streamed_{n}")
    assert single and streamed, f"missing mode pair at len {n}: {sorted(by)}"
    for r in (single, streamed):
        missing = {"name", "mode", "len", "points", "chunk_points", "chunks_out",
                   "rows_per_sec", "p50_ms", "p99_ms"} - set(r)
        assert not missing, f"record missing {missing}: {r}"
        assert r["rows_per_sec"] > 0, f"degenerate record: {r}"
        assert r["p99_ms"] >= r["p50_ms"] > 0, f"bad percentiles: {r}"
    assert streamed["chunks_out"] > 0, f"streamed row never chunked: {streamed}"
    assert single["chunks_out"] == 0, f"single-frame row chunked: {single}"
largest = max(lens)
ratio = by[f"streamed_{largest}"]["p50_ms"] / by[f"single_{largest}"]["p50_ms"]
print(f"BENCH_ingress_stream.json OK ({len(lens)} payload sizes; streamed/single "
      f"p50 at {largest}: {ratio:.2f}x)")
PY
else
    grep -q '"streamed_' BENCH_ingress_stream.json \
        && grep -q '"single_' BENCH_ingress_stream.json \
        && grep -q '"p99_ms"' BENCH_ingress_stream.json \
        && echo "BENCH_ingress_stream.json OK (grep check; python3 unavailable)"
fi

# Decode artifact: a one-iteration smoke through the decode bench must
# emit BENCH_decode.json with paired cached/full records per context
# length so the sessions-vs-recompute trajectory accumulates across PRs.
# The speedup itself is only asserted as a warning at this scale (1
# iteration, 8 tokens is noise-dominated); the full-scale run is manual.
echo "==> decode smoke: FFC_BENCH_ITERS=1 cargo bench --bench table_decode"
rm -f BENCH_decode.json
FFC_BENCH_ITERS=1 FFC_BENCH_MAX_SECS=60 FFC_DECODE_TOKENS=8 \
    cargo bench --bench table_decode >/dev/null
test -s BENCH_decode.json || { echo "FAIL: BENCH_decode.json missing or empty"; exit 1; }
if command -v python3 >/dev/null 2>&1; then
    python3 - <<'PY'
import json
recs = json.load(open("BENCH_decode.json"))
by = {r["name"]: r for r in recs}
pairs = []
for name in by:
    if name.startswith("decode_cached_n"):
        n = name[len("decode_cached_n"):]
        full = by.get(f"decode_full_n{n}")
        assert full, f"missing full-recompute record for context {n}: {sorted(by)}"
        pairs.append((int(n), by[name], full))
assert len(pairs) >= 2, f"need >=2 context lengths, got {sorted(by)}"
for n, cached, full in sorted(pairs):
    for r in (cached, full):
        missing = {"name", "n", "mean_ns", "median_ns", "p95_ns"} - set(r)
        assert not missing, f"record missing {missing}: {r}"
        assert r["n"] == n and r["median_ns"] > 0, f"degenerate record: {r}"
largest = max(pairs)
speedup = largest[2]["median_ns"] / largest[1]["median_ns"]
print(f"BENCH_decode.json OK ({len(pairs)} contexts; cached vs full at "
      f"n={largest[0]}: {speedup:.2f}x)")
if speedup <= 1.0:
    print(f"WARN: cached decode did not beat full recompute this run ({speedup:.2f}x)")
PY
else
    grep -q '"decode_cached_n' BENCH_decode.json \
        && grep -q '"decode_full_n' BENCH_decode.json \
        && echo "BENCH_decode.json OK (grep check; python3 unavailable)"
fi

# The incremental path is only trustworthy if the parity tests actually
# ran: the session chain must match the time-domain oracle token-for-token.
echo "==> decode parity: cargo test decode_parity"
parity_out=$(cargo test --release -q decode_parity 2>&1) || {
    echo "$parity_out"; echo "FAIL: decode parity tests failed"; exit 1; }
echo "$parity_out" | grep -Eq '[1-9][0-9]* passed' \
    || { echo "$parity_out"; echo "FAIL: no decode_parity test ran"; exit 1; }
echo "decode parity OK"

# Perf smoke: a one-iteration bench run must produce the machine-readable
# perf artifact (BENCH_table3.json is how the perf trajectory accumulates
# across PRs), and the artifact must be well-formed.
echo "==> perf smoke: FFC_BENCH_ITERS=1 cargo bench --bench table3_conv"
rm -f BENCH_table3.json
FFC_BENCH_ITERS=1 FFC_BENCH_MAX_SECS=3 cargo bench --bench table3_conv >/dev/null
test -s BENCH_table3.json || { echo "FAIL: BENCH_table3.json missing or empty"; exit 1; }
if command -v python3 >/dev/null 2>&1; then
    python3 - <<'PY'
import json
recs = json.load(open("BENCH_table3.json"))
assert isinstance(recs, list) and recs, "no records"
for r in recs:
    missing = {"name", "n", "mean_ns", "median_ns", "p95_ns"} - set(r)
    assert not missing, f"record missing {missing}: {r}"
print(f"BENCH_table3.json OK ({len(recs)} records)")
PY
else
    grep -q '"mean_ns"' BENCH_table3.json && grep -q '"name"' BENCH_table3.json \
        && echo "BENCH_table3.json OK (grep check; python3 unavailable)"
fi

# GEMM kernel artifact: the microkernel bench must emit BENCH_gemm.json
# with the per-tier stage-GEMM records (portable vs FMA tiers vs the f32
# serving tier) and the autotuned-vs-model dispatch pairs. The SIMD
# speedup and the tuned-never-loses bar are asserted as warnings at
# smoke scale (1 iteration is jitter-dominated); full-scale runs are
# where the acceptance numbers come from.
echo "==> gemm kernel smoke: FFC_BENCH_ITERS=1 cargo bench --bench table_gemm"
rm -f BENCH_gemm.json
FFC_BENCH_ITERS=1 FFC_BENCH_MAX_SECS=5 cargo bench --bench table_gemm >/dev/null
test -s BENCH_gemm.json || { echo "FAIL: BENCH_gemm.json missing or empty"; exit 1; }
if command -v python3 >/dev/null 2>&1; then
    python3 - <<'PY'
import json
recs = json.load(open("BENCH_gemm.json"))
by = {r["name"]: r for r in recs}
for r in recs:
    missing = {"name", "n", "kernel", "precision", "median_ns", "gflops"} - set(r)
    assert not missing, f"record missing {missing}: {r}"
    assert r["median_ns"] > 0, f"degenerate record: {r}"
gemm = [r for r in recs if r["name"].startswith("gemm_") and r["precision"] == "f64"]
assert gemm, f"no f64 gemm records: {sorted(by)}"
lens = sorted({r["n"] for r in gemm})
assert len(lens) >= 3, f"need >=3 gemm lengths, got {lens}"
for n in lens:
    port = by.get(f"gemm_portable_n{n}")
    assert port, f"missing portable baseline at n={n}: {sorted(by)}"
    f32 = [r for r in recs if r["precision"] == "f32" and r["n"] == n]
    assert f32, f"missing f32 serving-tier record at n={n}"
simd = by.get("gemm_avx2fma_n4096")
if simd:
    speedup = by["gemm_portable_n4096"]["median_ns"] / simd["median_ns"]
    print(f"BENCH_gemm.json: avx2fma vs portable at n=4096: {speedup:.2f}x")
    if speedup < 1.5:
        print(f"WARN: AVX2+FMA under the 1.5x bar this run ({speedup:.2f}x)")
else:
    print("BENCH_gemm.json: no AVX2+FMA tier on this host (portable/scalar only)")
pairs = 0
for n in lens:
    model = by.get(f"dispatch_model_n{n}")
    tuned = by.get(f"dispatch_tuned_n{n}")
    assert model and tuned, f"missing dispatch pair at n={n}: {sorted(by)}"
    pairs += 1
    ratio = tuned["median_ns"] / model["median_ns"]
    if ratio > 1.10:
        print(f"WARN: tuned dispatch slower than model at n={n} ({ratio:.2f}x)")
print(f"BENCH_gemm.json OK ({len(recs)} records, {pairs} dispatch pairs)")
PY
else
    grep -q '"gemm_portable_n4096"' BENCH_gemm.json \
        && grep -q '"dispatch_tuned_n' BENCH_gemm.json \
        && echo "BENCH_gemm.json OK (grep check; python3 unavailable)"
fi

# Memory artifact: the table16 bench measures steady-state allocations
# per request (fresh-alloc plan wrappers vs the workspace hot path) and
# workspace peak bytes; the workspace refactor's allocation drop must be
# visible in BENCH_memory.json.
echo "==> memory smoke: cargo bench --bench table16_memory"
rm -f BENCH_memory.json
cargo bench --bench table16_memory >/dev/null
test -s BENCH_memory.json || { echo "FAIL: BENCH_memory.json missing or empty"; exit 1; }
if command -v python3 >/dev/null 2>&1; then
    python3 - <<'PY'
import json
recs = json.load(open("BENCH_memory.json"))
by = {r["name"]: r for r in recs}
for r in recs:
    missing = {"name", "n", "allocs_per_request", "bytes_per_request",
               "workspace_peak_bytes"} - set(r)
    assert not missing, f"record missing {missing}: {r}"
fresh = by.get("plan_conv_fresh")
ws = by.get("plan_conv_ws")
assert fresh and ws, f"missing memory records: {sorted(by)}"
assert ws["allocs_per_request"] < 1.0, \
    f"workspace path must be allocation-free at steady state: {ws}"
assert fresh["allocs_per_request"] > ws["allocs_per_request"], \
    f"no allocation drop: fresh={fresh} ws={ws}"
assert ws["workspace_peak_bytes"] > 0, f"workspace peak missing: {ws}"
print(f"BENCH_memory.json OK (allocs/request {fresh['allocs_per_request']:.0f} -> "
      f"{ws['allocs_per_request']:.0f}, ws peak {ws['workspace_peak_bytes']} B)")
PY
else
    grep -q '"plan_conv_ws"' BENCH_memory.json \
        && grep -q '"plan_conv_fresh"' BENCH_memory.json \
        && echo "BENCH_memory.json OK (grep check; python3 unavailable)"
fi

# Chunked-streaming artifact: the genome-length act of table7 runs one
# >=1M-point causal partial conv through the chunked bucket and through
# a monolithic bucket of the same length, and BENCH_chunked.json must
# prove the memory headline mechanically: chunked workspace peak at most
# 1/8 of the monolithic peak (it is typically ~100x smaller). Throughput
# is recorded for the trajectory but not gated at 1-iteration scale.
echo "==> chunked conv smoke: FFC_BENCH_ITERS=1 cargo bench --bench table7_partial"
rm -f BENCH_chunked.json
FFC_BENCH_ITERS=1 FFC_BENCH_MAX_SECS=60 cargo bench --bench table7_partial >/dev/null
test -s BENCH_chunked.json || { echo "FAIL: BENCH_chunked.json missing or empty"; exit 1; }
if command -v python3 >/dev/null 2>&1; then
    python3 - <<'PY'
import json
recs = json.load(open("BENCH_chunked.json"))
by = {r["name"]: r for r in recs}
chunked = by.get("chunked")
mono = by.get("monolithic")
assert chunked and mono, f"missing chunked/monolithic pair: {sorted(by)}"
for r in (chunked, mono):
    missing = {"name", "n", "filter_len", "median_ms", "points_per_sec",
               "workspace_peak_bytes"} - set(r)
    assert not missing, f"record missing {missing}: {r}"
    assert r["points_per_sec"] > 0 and r["median_ms"] > 0, f"degenerate record: {r}"
assert chunked["n"] == mono["n"] >= 1 << 20, \
    f"genome-length record must be >=1M points: {chunked['n']}"
ratio = mono["workspace_peak_bytes"] / max(chunked["workspace_peak_bytes"], 1)
assert ratio >= 8.0, \
    f"chunked workspace peak must be <= 1/8 of monolithic, got {ratio:.2f}x " \
    f"({chunked['workspace_peak_bytes']} vs {mono['workspace_peak_bytes']} B)"
tp = chunked["points_per_sec"] / mono["points_per_sec"]
print(f"BENCH_chunked.json OK (workspace peak {ratio:.0f}x smaller chunked; "
      f"chunked/monolithic throughput {tp:.2f}x at n={chunked['n']})")
PY
else
    grep -q '"chunked"' BENCH_chunked.json \
        && grep -q '"monolithic"' BENCH_chunked.json \
        && grep -q '"workspace_peak_bytes"' BENCH_chunked.json \
        && echo "BENCH_chunked.json OK (grep check; python3 unavailable)"
fi

lint_mode="${FFC_CI_LINT:-advisory}"

if cargo fmt --version >/dev/null 2>&1; then
    echo "==> cargo fmt --check (${lint_mode})"
    if ! cargo fmt --check; then
        if [ "${lint_mode}" = "strict" ]; then
            exit 1
        fi
        echo "(fmt differences above are advisory; FFC_CI_LINT=strict to enforce)"
    fi
else
    echo "==> cargo fmt not installed; skipping"
fi

if cargo clippy --version >/dev/null 2>&1; then
    echo "==> cargo clippy -- -D warnings (${lint_mode})"
    if ! cargo clippy --all-targets -- -D warnings; then
        if [ "${lint_mode}" = "strict" ]; then
            exit 1
        fi
        echo "(clippy findings above are advisory; FFC_CI_LINT=strict to enforce)"
    fi
else
    echo "==> cargo clippy not installed; skipping"
fi

echo "==> ci.sh OK"
